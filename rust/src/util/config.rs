//! Experiment configuration: typed struct + manifest (JSON) loading +
//! CLI overrides (`--key value`). Every launcher entry point
//! (`decentlam` binary, examples, benches) builds one of these.
//!
//! The manifest is the canonical config surface (DESIGN.md §10):
//! [`Config::from_manifest`] parses a fail-closed JSON object (unknown
//! keys are hard errors, every error names its path) and
//! [`Config::to_manifest`] emits the canonical form that reparses to an
//! equal `Config` — the round trip `Config -> to_manifest ->
//! from_manifest == Config` is pinned by tests across every optimizer
//! and spec. CLI flags are a thin translation layer over the same
//! per-key dispatch ([`Config::apply_kv`]).
//!
//! The four subsystem specs (`--faults`, `--codec`, `--async`,
//! `--churn`) are TYPED fields here, parsed exactly once at the
//! boundary (`apply_kv` / `from_manifest`) through the shared
//! [`crate::util::kvspec::KvSpec`] grammar — downstream code never
//! re-parses strings. Their seeds stay "inherit the run seed" until
//! [`crate::coordinator::Trainer`] resolves them via `with_run_seed`.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::comm::codec::CodecSpec;
use crate::elastic::ChurnSpec;
use crate::sim::{AsyncSpec, FaultSpec};

use super::cli::Args;
use super::json::{Cursor, Value};

/// Learning-rate schedule, following the paper's §7.1 protocol.
#[derive(Debug, Clone, PartialEq)]
pub enum LrSchedule {
    /// Constant learning rate (the theory sections / bias experiments).
    Constant,
    /// Linear warmup for `warmup_steps`, then ×0.1 decays at the given
    /// step milestones (the small-batch protocol of Goyal et al.).
    WarmupStep { warmup_steps: usize, milestones: Vec<usize> },
    /// Linear warmup then cosine annealing to zero over `total_steps`
    /// (the large-batch protocol of You et al.).
    WarmupCosine { warmup_steps: usize, total_steps: usize },
}

impl LrSchedule {
    /// Multiplier applied to the base LR at step `k`.
    pub fn factor(&self, k: usize) -> f64 {
        match self {
            LrSchedule::Constant => 1.0,
            LrSchedule::WarmupStep { warmup_steps, milestones } => {
                if k < *warmup_steps {
                    (k + 1) as f64 / *warmup_steps as f64
                } else {
                    let hits = milestones.iter().filter(|&&m| k >= m).count() as i32;
                    0.1f64.powi(hits)
                }
            }
            LrSchedule::WarmupCosine { warmup_steps, total_steps } => {
                if k < *warmup_steps {
                    (k + 1) as f64 / *warmup_steps as f64
                } else {
                    let t = (k - warmup_steps) as f64
                        / (total_steps.saturating_sub(*warmup_steps)).max(1) as f64;
                    0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos())
                }
            }
        }
    }
}

/// One experiment run.
#[derive(Debug, Clone, PartialEq)]
pub struct Config {
    /// Number of computing nodes n.
    pub nodes: usize,
    /// Topology name: ring | mesh | full | star | sym-exp | one-peer-exp |
    /// bipartite | erdos.
    pub topology: String,
    /// Optimizer: decentlam | dmsgd | dsgd | pmsgd | pmsgd-lars |
    /// da-dmsgd | awc-dmsgd | slowmo | qg-dmsgd | d2-dmsgd.
    pub optimizer: String,
    /// Model name from the AOT manifest ("native-logreg"/"native-mlp" use
    /// the in-crate gradient engines instead of PJRT).
    pub model: String,
    /// TOTAL batch per iteration, across all nodes. Realized as per-node
    /// micro-batches × gradient accumulation (DESIGN.md §2).
    pub total_batch: usize,
    /// Micro-batch per node per gradient evaluation.
    pub micro_batch: usize,
    /// Training steps (outer iterations).
    pub steps: usize,
    /// Base learning rate, linearly scaled by total batch (paper §7.1)
    /// when `linear_scaling` is set.
    pub lr: f64,
    pub linear_scaling: bool,
    /// Reference batch for linear scaling (lr_effective = lr * B/B_ref).
    pub lr_ref_batch: usize,
    /// Cap on the linear-scaling factor (Goyal et al. note linear scaling
    /// breaks past a point; our synthetic task destabilizes above ~8x).
    pub max_lr_scale: f64,
    pub momentum: f64,
    pub schedule: LrSchedule,
    /// Dirichlet concentration controlling inter-node heterogeneity
    /// (small = heterogeneous; the paper's b² knob).
    pub dirichlet_alpha: f64,
    pub seed: u64,
    /// Directory with AOT artifacts.
    pub artifacts: String,
    /// SlowMo sync period (steps) and slow-momentum coefficient.
    pub slowmo_period: usize,
    pub slowmo_beta: f64,
    /// Use positive-definite (lazy) Metropolis weights (Thm. 1 ablation).
    pub positive_definite: bool,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    /// Worker threads for the gradient/exchange/update phases
    /// (0 = one per hardware thread, 1 = serial).
    pub threads: usize,
    /// Fault injection, parsed from `drop=0.1,straggle=0.05,seed=7`
    /// (None = fault-free; see [`FaultSpec`]). The fault seed defaults
    /// to `seed` when the spec omits `seed=` (resolved in the trainer).
    pub faults: Option<FaultSpec>,
    /// Gossip payload codec, parsed from `int8,ef=true,seed=7` or
    /// `topk,k=0.05` (None = raw fp32; see [`CodecSpec`]). The codec
    /// seed defaults to `seed` when the spec omits `seed=`.
    pub codec: Option<CodecSpec>,
    /// Asynchronous execution, parsed from `tau=2,spread=4,jitter=0.2`
    /// (None = synchronous rounds; see [`AsyncSpec`]). Nodes run on
    /// heterogeneous simulated clocks and mix neighbor payloads up to
    /// `tau` rounds stale; requires a static topology. The clock seed
    /// defaults to `seed` when the spec omits `seed=`.
    pub async_mode: Option<AsyncSpec>,
    /// Elastic membership, parsed from `join=0.02,leave=0.02,nmin=8,
    /// nmax=64,seed=7` (None = fixed roster; see [`ChurnSpec`]). Nodes
    /// join/leave mid-run on a seeded schedule; the workload must
    /// supply `nmax` shards and `nodes` is the initial active count.
    /// Requires a static topology and synchronous execution. The churn
    /// seed defaults to `seed` when the spec omits `seed=`.
    pub churn: Option<ChurnSpec>,
    /// Telemetry JSONL sink path (`--telemetry out.jsonl`; None = off).
    /// Deliberately EXCLUDED from [`Config::to_manifest`]: where a run
    /// streams its events is observability plumbing, not run identity —
    /// manifests, sha digests and snapshots stay byte-identical with
    /// telemetry on or off (DESIGN.md §11).
    pub telemetry: Option<String>,
    /// Telemetry flush cadence in events (`--telemetry out.jsonl,flush=K`;
    /// 0 = only end-of-run/drop flushes). CLI-only, like `telemetry`:
    /// when bytes reach the OS is not run identity (DESIGN.md §11).
    pub telemetry_flush: usize,
    /// Emit a `metrics` event every K steps (`--metrics every=K` or
    /// `--metrics K`; 0 = off). CLI-only: the metrics cadence never
    /// enters manifests or digests (DESIGN.md §14).
    pub metrics_every: usize,
    /// Emit a `timing` event every K steps (`--profile` = every step,
    /// `--profile every=K`; 0 = off). CLI-only, and `timing` lines are
    /// excluded from replay equality entirely (DESIGN.md §14).
    pub profile_every: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nodes: 8,
            topology: "sym-exp".into(),
            optimizer: "decentlam".into(),
            model: "native-mlp".into(),
            total_batch: 512,
            micro_batch: 64,
            steps: 300,
            lr: 0.1,
            linear_scaling: true,
            lr_ref_batch: 256,
            max_lr_scale: 8.0,
            momentum: 0.9,
            schedule: LrSchedule::WarmupStep { warmup_steps: 20, milestones: vec![150, 250] },
            dirichlet_alpha: 0.3,
            seed: 1,
            artifacts: "artifacts".into(),
            slowmo_period: 12,
            slowmo_beta: 0.7,
            positive_definite: false,
            eval_every: 0,
            threads: 0,
            faults: None,
            codec: None,
            async_mode: None,
            churn: None,
            telemetry: None,
            telemetry_flush: crate::telemetry::sink::DEFAULT_FLUSH_EVERY,
            metrics_every: 0,
            profile_every: 0,
        }
    }
}

impl Config {
    /// Effective base LR after linear scaling.
    pub fn scaled_lr(&self) -> f64 {
        if self.linear_scaling {
            let scale =
                (self.total_batch as f64 / self.lr_ref_batch as f64).min(self.max_lr_scale);
            self.lr * scale
        } else {
            self.lr
        }
    }

    /// LR at step k.
    pub fn lr_at(&self, k: usize) -> f32 {
        (self.scaled_lr() * self.schedule.factor(k)) as f32
    }

    /// Gradient-accumulation micro-steps per node per iteration.
    pub fn accum_steps(&self) -> usize {
        let per_node = (self.total_batch + self.nodes - 1) / self.nodes;
        ((per_node + self.micro_batch - 1) / self.micro_batch).max(1)
    }

    /// Apply `--key value` CLI overrides.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for (k, v) in &args.flags {
            self.apply_kv(k, v)
                .with_context(|| format!("applying --{k} {v}"))?;
        }
        Ok(())
    }

    /// Set one field by name.
    pub fn apply_kv(&mut self, key: &str, v: &str) -> Result<()> {
        match key {
            "nodes" => self.nodes = v.parse()?,
            "topology" => self.topology = v.into(),
            "optimizer" | "opt" => self.optimizer = v.into(),
            "model" => self.model = v.into(),
            "total-batch" | "batch" => self.total_batch = v.parse()?,
            "micro-batch" => self.micro_batch = v.parse()?,
            "steps" => self.steps = v.parse()?,
            "lr" => self.lr = v.parse()?,
            "linear-scaling" => self.linear_scaling = v.parse()?,
            "lr-ref-batch" => self.lr_ref_batch = v.parse()?,
            "max-lr-scale" => self.max_lr_scale = v.parse()?,
            "momentum" | "beta" => self.momentum = v.parse()?,
            "schedule" => {
                self.schedule = match v {
                    "constant" => LrSchedule::Constant,
                    "warmup-step" => LrSchedule::WarmupStep {
                        warmup_steps: self.steps / 20,
                        milestones: vec![self.steps / 3, 2 * self.steps / 3],
                    },
                    "warmup-cosine" => LrSchedule::WarmupCosine {
                        warmup_steps: self.steps / 6,
                        total_steps: self.steps,
                    },
                    other => bail!("unknown schedule `{other}`"),
                }
            }
            "alpha" | "dirichlet-alpha" => self.dirichlet_alpha = v.parse()?,
            "seed" => self.seed = v.parse()?,
            "artifacts" => self.artifacts = v.into(),
            "slowmo-period" => self.slowmo_period = v.parse()?,
            "slowmo-beta" => self.slowmo_beta = v.parse()?,
            "positive-definite" | "pd" => self.positive_definite = v.parse()?,
            "eval-every" => self.eval_every = v.parse()?,
            "threads" => self.threads = v.parse()?,
            // The four subsystem specs parse into their TYPED fields
            // right here, with default_seed 0 — "inherit the run seed"
            // is carried by the spec's own seed_from_run flag and
            // resolved in Trainer::new, where the run seed is final.
            // An empty value clears the spec (subsystem off).
            "faults" => self.faults = opt_spec(v, FaultSpec::parse)?,
            "codec" => self.codec = opt_spec(v, CodecSpec::parse)?,
            "async" => self.async_mode = opt_spec(v, AsyncSpec::parse)?,
            "churn" => self.churn = opt_spec(v, ChurnSpec::parse)?,
            // Observability plumbing, not run identity: settable from
            // the CLI but never serialized into manifests (empty clears).
            // `--telemetry out.jsonl,flush=K` sets the flush cadence too.
            "telemetry" => match v.split_once(",flush=") {
                Some((path, flush)) => {
                    let flush: usize =
                        flush.parse().with_context(|| format!("flush cadence `{flush}`"))?;
                    self.telemetry =
                        if path.trim().is_empty() { None } else { Some(path.to_string()) };
                    self.telemetry_flush = flush;
                }
                None => {
                    self.telemetry =
                        if v.trim().is_empty() { None } else { Some(v.to_string()) }
                }
            },
            "metrics" => self.metrics_every = cadence(v)?,
            "profile" => self.profile_every = cadence(v)?,
            "config" | "out" | "csv" | "quick" | "bw-gbps" | "fast" => {} // consumed elsewhere
            other => bail!("unknown config key `{other}`"),
        }
        Ok(())
    }

    /// Cross-field invariants, validated eagerly (the scenario runner
    /// and `Trainer::new` both call this; error strings are pinned by
    /// the rejected-combo corpus). Field-local validity is already
    /// guaranteed by the typed spec fields.
    pub fn validate(&self) -> Result<()> {
        let kind = crate::topology::Kind::parse(&self.topology)?;
        let optimizer =
            crate::optim::build(&self.optimizer, self.slowmo_period, self.slowmo_beta)?;
        if let Some(churn) = self.churn {
            // Churn models synchronous rounds over an elastic roster on
            // a fixed neighbor structure (DESIGN.md §9).
            ensure!(
                !kind.time_varying(),
                "--churn requires a static topology; `{}` changes neighbors per step",
                self.topology
            );
            ensure!(
                self.async_mode.is_none(),
                "--churn models synchronous rounds over an elastic roster; composing \
                 with --async (churn-aware schedules) is an open item — see ROADMAP.md"
            );
            churn.resolve(self.nodes)?;
        }
        if self.async_mode.is_some() {
            match optimizer.comm_pattern() {
                crate::optim::CommPattern::NeighborPlusPeriodicAllReduce { .. } => {
                    bail!(
                        "--async models pure gossip rounds; `{}`'s periodic all-reduce \
                         is a global barrier (run pmsgd for the barrier baseline)",
                        self.optimizer
                    );
                }
                crate::optim::CommPattern::Neighbor { .. } => {
                    ensure!(
                        !kind.time_varying(),
                        "--async requires a static topology; `{}` changes neighbors per step",
                        self.topology
                    );
                }
                crate::optim::CommPattern::AllReduce => {}
            }
        }
        Ok(())
    }

    /// Canonical manifest form: a flat JSON object with dashed keys
    /// (the `apply_kv` names), a structured `schedule`, and the spec
    /// fields as their canonical spec strings (present only when on).
    /// Reparses via [`Config::from_manifest`] to an equal `Config`.
    pub fn to_manifest(&self) -> Value {
        let schedule = match &self.schedule {
            LrSchedule::Constant => Value::obj(vec![("kind", Value::Str("constant".into()))]),
            LrSchedule::WarmupStep { warmup_steps, milestones } => Value::obj(vec![
                ("kind", Value::Str("warmup-step".into())),
                ("warmup-steps", Value::Num(*warmup_steps as f64)),
                (
                    "milestones",
                    Value::Arr(milestones.iter().map(|&m| Value::Num(m as f64)).collect()),
                ),
            ]),
            LrSchedule::WarmupCosine { warmup_steps, total_steps } => Value::obj(vec![
                ("kind", Value::Str("warmup-cosine".into())),
                ("warmup-steps", Value::Num(*warmup_steps as f64)),
                ("total-steps", Value::Num(*total_steps as f64)),
            ]),
        };
        let mut pairs = vec![
            ("nodes", Value::Num(self.nodes as f64)),
            ("topology", Value::Str(self.topology.clone())),
            ("optimizer", Value::Str(self.optimizer.clone())),
            ("model", Value::Str(self.model.clone())),
            ("total-batch", Value::Num(self.total_batch as f64)),
            ("micro-batch", Value::Num(self.micro_batch as f64)),
            ("steps", Value::Num(self.steps as f64)),
            ("lr", Value::Num(self.lr)),
            ("linear-scaling", Value::Bool(self.linear_scaling)),
            ("lr-ref-batch", Value::Num(self.lr_ref_batch as f64)),
            ("max-lr-scale", Value::Num(self.max_lr_scale)),
            ("momentum", Value::Num(self.momentum)),
            ("schedule", schedule),
            ("dirichlet-alpha", Value::Num(self.dirichlet_alpha)),
            // Seed as a string: u64 seeds can exceed f64's exact
            // integer range, and JSON numbers here are f64.
            ("seed", Value::Str(format!("{}", self.seed))),
            ("artifacts", Value::Str(self.artifacts.clone())),
            ("slowmo-period", Value::Num(self.slowmo_period as f64)),
            ("slowmo-beta", Value::Num(self.slowmo_beta)),
            ("positive-definite", Value::Bool(self.positive_definite)),
            ("eval-every", Value::Num(self.eval_every as f64)),
            ("threads", Value::Num(self.threads as f64)),
        ];
        if let Some(s) = &self.faults {
            pairs.push(("faults", Value::Str(s.to_spec_string())));
        }
        if let Some(s) = &self.codec {
            pairs.push(("codec", Value::Str(s.to_spec_string())));
        }
        if let Some(s) = &self.async_mode {
            pairs.push(("async", Value::Str(s.to_spec_string())));
        }
        if let Some(s) = &self.churn {
            pairs.push(("churn", Value::Str(s.to_spec_string())));
        }
        Value::obj(pairs)
    }

    /// Parse a manifest object, fail-closed: unknown keys are hard
    /// errors, every error names the offending path. Accepts the
    /// `apply_kv` aliases (`opt`, `batch`, `beta`, `alpha`, `pd`) and
    /// both schedule forms — the structured object [`Config::to_manifest`]
    /// emits, or the CLI's derive-from-steps string form.
    pub fn from_manifest(c: &Cursor) -> Result<Config> {
        let mut cfg = Config::default();
        // Steps first: the string-form `schedule` derives its warmup
        // and milestones from it, whatever the key order.
        if let Some(x) = c.opt("steps") {
            cfg.steps = x.as_usize()?;
        }
        for (key, x) in c.entries()? {
            match key {
                "steps" => {}
                "nodes" => cfg.nodes = x.as_usize()?,
                "topology" => cfg.topology = x.as_str()?.to_string(),
                "optimizer" | "opt" => cfg.optimizer = x.as_str()?.to_string(),
                "model" => cfg.model = x.as_str()?.to_string(),
                "total-batch" | "batch" => cfg.total_batch = x.as_usize()?,
                "micro-batch" => cfg.micro_batch = x.as_usize()?,
                "lr" => cfg.lr = x.as_f64()?,
                "linear-scaling" => cfg.linear_scaling = x.as_bool()?,
                "lr-ref-batch" => cfg.lr_ref_batch = x.as_usize()?,
                "max-lr-scale" => cfg.max_lr_scale = x.as_f64()?,
                "momentum" | "beta" => cfg.momentum = x.as_f64()?,
                "schedule" => cfg.schedule = schedule_from_manifest(&x, cfg.steps)?,
                "alpha" | "dirichlet-alpha" => cfg.dirichlet_alpha = x.as_f64()?,
                // Seed: canonical string form (exact u64) or a number.
                "seed" => {
                    cfg.seed = match x.value() {
                        Value::Str(s) => s
                            .parse()
                            .map_err(|e| anyhow::anyhow!("{}: {e}", x.path()))?,
                        _ => x.as_u64()?,
                    }
                }
                "artifacts" => cfg.artifacts = x.as_str()?.to_string(),
                "slowmo-period" => cfg.slowmo_period = x.as_usize()?,
                "slowmo-beta" => cfg.slowmo_beta = x.as_f64()?,
                "positive-definite" | "pd" => cfg.positive_definite = x.as_bool()?,
                "eval-every" => cfg.eval_every = x.as_usize()?,
                "threads" => cfg.threads = x.as_usize()?,
                "faults" => {
                    cfg.faults =
                        opt_spec(x.as_str()?, FaultSpec::parse).with_context(|| x.path().to_string())?
                }
                "codec" => {
                    cfg.codec =
                        opt_spec(x.as_str()?, CodecSpec::parse).with_context(|| x.path().to_string())?
                }
                "async" => {
                    cfg.async_mode =
                        opt_spec(x.as_str()?, AsyncSpec::parse).with_context(|| x.path().to_string())?
                }
                "churn" => {
                    cfg.churn =
                        opt_spec(x.as_str()?, ChurnSpec::parse).with_context(|| x.path().to_string())?
                }
                "config" | "out" | "csv" | "quick" | "bw-gbps" | "fast" | "telemetry"
                | "metrics" | "profile" => {
                    bail!("{}: `{key}` is a CLI-only flag, not a config field", c.path());
                }
                other => bail!("{}: unknown config key `{other}`", c.path()),
            }
        }
        Ok(cfg)
    }

    /// Load a JSON config file — the manifest path, fail-closed:
    /// unknown top-level keys are rejected (they were silently ignored
    /// before the scenario registry; see DESIGN.md §10).
    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text)?;
        Config::from_manifest(&Cursor::root(&v, "config"))
    }

    /// Build from CLI (optionally `--config file.json` first).
    pub fn from_args(args: &Args) -> Result<Config> {
        let mut cfg = match args.get("config") {
            Some(p) => Config::load(Path::new(p))?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }
}

/// Parse an every-K observability cadence: `every=K` or a bare `K`,
/// with the bare-flag forms `true` (every step) and `false`/empty (off)
/// so `--metrics` / `--profile` work without a value.
fn cadence(v: &str) -> Result<usize> {
    let v = v.trim();
    match v {
        "" | "false" => Ok(0),
        "true" => Ok(1),
        _ => {
            let k = v.strip_prefix("every=").unwrap_or(v);
            k.parse().with_context(|| format!("cadence `{v}` (expected every=K or K)"))
        }
    }
}

/// Parse one spec field: empty/whitespace = subsystem off, otherwise
/// the spec's kv grammar with default_seed 0 (run-seed inheritance is
/// the spec's own `seed_from_run` flag).
fn opt_spec<T>(v: &str, parse: fn(&str, u64) -> Result<T>) -> Result<Option<T>> {
    if v.trim().is_empty() {
        return Ok(None);
    }
    parse(v, 0).map(Some)
}

/// Both schedule forms: the CLI string (`constant` | `warmup-step` |
/// `warmup-cosine`, parameters derived from `steps`) and the structured
/// object `to_manifest` emits (parameters explicit, fail-closed).
fn schedule_from_manifest(x: &Cursor, steps: usize) -> Result<LrSchedule> {
    if let Ok(name) = x.value().as_str() {
        return match name {
            "constant" => Ok(LrSchedule::Constant),
            "warmup-step" => Ok(LrSchedule::WarmupStep {
                warmup_steps: steps / 20,
                milestones: vec![steps / 3, 2 * steps / 3],
            }),
            "warmup-cosine" => {
                Ok(LrSchedule::WarmupCosine { warmup_steps: steps / 6, total_steps: steps })
            }
            other => bail!("{}: unknown schedule `{other}`", x.path()),
        };
    }
    let kind = x.get("kind")?;
    match kind.as_str()? {
        "constant" => {
            x.deny_unknown(&["kind"])?;
            Ok(LrSchedule::Constant)
        }
        "warmup-step" => {
            x.deny_unknown(&["kind", "warmup-steps", "milestones"])?;
            let milestones = x
                .get("milestones")?
                .items()?
                .iter()
                .map(|m| m.as_usize())
                .collect::<Result<Vec<_>>>()?;
            Ok(LrSchedule::WarmupStep {
                warmup_steps: x.get("warmup-steps")?.as_usize()?,
                milestones,
            })
        }
        "warmup-cosine" => {
            x.deny_unknown(&["kind", "warmup-steps", "total-steps"])?;
            Ok(LrSchedule::WarmupCosine {
                warmup_steps: x.get("warmup-steps")?.as_usize()?,
                total_steps: x.get("total-steps")?.as_usize()?,
            })
        }
        other => bail!("{}: unknown schedule `{other}`", kind.path()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = Config::default();
        assert_eq!(c.nodes, 8);
        assert!(c.accum_steps() >= 1);
    }

    #[test]
    fn linear_scaling_math() {
        let mut c = Config::default();
        c.lr = 0.1;
        c.lr_ref_batch = 256;
        c.total_batch = 1024;
        assert!((c.scaled_lr() - 0.4).abs() < 1e-12);
        c.linear_scaling = false;
        assert!((c.scaled_lr() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn accum_steps_covers_total_batch() {
        let mut c = Config::default();
        c.nodes = 8;
        c.micro_batch = 64;
        for tb in [64, 512, 513, 4096] {
            c.total_batch = tb;
            let per_node_capacity = c.accum_steps() * c.micro_batch * c.nodes;
            assert!(per_node_capacity >= tb, "tb={tb}");
        }
    }

    #[test]
    fn warmup_step_schedule() {
        let s = LrSchedule::WarmupStep { warmup_steps: 10, milestones: vec![100, 200] };
        assert!(s.factor(0) < s.factor(5));
        assert!((s.factor(9) - 1.0).abs() < 1e-12);
        assert!((s.factor(50) - 1.0).abs() < 1e-12);
        assert!((s.factor(150) - 0.1).abs() < 1e-12);
        assert!((s.factor(250) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn warmup_cosine_schedule() {
        let s = LrSchedule::WarmupCosine { warmup_steps: 10, total_steps: 110 };
        assert!((s.factor(9) - 1.0).abs() < 1e-12);
        assert!(s.factor(60) < 1.0 && s.factor(60) > 0.0);
        assert!(s.factor(109) < 0.01);
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["--nodes", "4", "--beta", "0.95", "--topology", "ring"]
                .iter()
                .map(|s| s.to_string()),
        );
        let cfg = Config::from_args(&args).unwrap();
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.momentum, 0.95);
        assert_eq!(cfg.topology, "ring");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = Config::default();
        assert!(c.apply_kv("warp-drive", "on").is_err());
    }

    #[test]
    fn faults_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("faults", "drop=0.1,straggle=0.05,seed=7").unwrap();
        let s = c.faults.unwrap();
        assert_eq!(s.drop, 0.1);
        assert_eq!(s.straggle, 0.05);
        assert_eq!(s.seed, 7);
        assert!(c.apply_kv("faults", "drop=2.0").is_err());
        assert!(c.apply_kv("faults", "gremlins=0.1").is_err());
        c.apply_kv("faults", "").unwrap();
        assert!(c.faults.is_none(), "empty value clears the spec");
    }

    #[test]
    fn codec_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("codec", "int8,ef=true,seed=3").unwrap();
        let s = c.codec.clone().unwrap();
        assert!(s.ef);
        assert_eq!(s.seed, 3);
        c.apply_kv("codec", "topk,k=0.05").unwrap();
        assert!(c.apply_kv("codec", "zfp").is_err());
        assert!(c.apply_kv("codec", "topk,k=2").is_err());
        assert!(c.apply_kv("codec", "int8,gremlins=1").is_err());
        c.apply_kv("codec", "").unwrap();
        assert!(c.codec.is_none(), "empty value clears the spec");
    }

    #[test]
    fn async_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("async", "tau=2,spread=4,jitter=0.2,seed=7").unwrap();
        let s = c.async_mode.clone().unwrap();
        assert_eq!(s.tau, 2);
        assert_eq!(s.seed, 7);
        c.apply_kv("async", "true").unwrap(); // bare --async: defaults
        assert_eq!(c.async_mode.clone().unwrap().tau, 1);
        assert!(c.apply_kv("async", "tau=99").is_err());
        assert!(c.apply_kv("async", "spread=0.1").is_err());
        assert!(c.apply_kv("async", "gremlins=1").is_err());
    }

    #[test]
    fn churn_key_validated_eagerly() {
        let mut c = Config::default();
        c.apply_kv("churn", "join=0.02,leave=0.02,nmin=8,nmax=64,seed=7").unwrap();
        let s = c.churn.unwrap();
        assert_eq!(s.join, 0.02);
        assert_eq!(s.nmax, 64);
        assert_eq!(s.seed, 7);
        c.apply_kv("churn", "true").unwrap(); // bare --churn: defaults
        assert!(c.churn.unwrap().is_zero());
        assert!(c.apply_kv("churn", "join=2").is_err());
        assert!(c.apply_kv("churn", "nmin=0").is_err());
        assert!(c.apply_kv("churn", "gremlins=1").is_err());
    }

    #[test]
    fn json_config_file() {
        let dir = std::env::temp_dir().join("decentlam_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("c.json");
        std::fs::write(&p, r#"{"nodes": 16, "optimizer": "dmsgd", "lr": 0.05}"#).unwrap();
        let cfg = Config::load(&p).unwrap();
        assert_eq!(cfg.nodes, 16);
        assert_eq!(cfg.optimizer, "dmsgd");
        assert!((cfg.lr - 0.05).abs() < 1e-12);
    }

    #[test]
    fn load_rejects_unknown_and_cli_only_keys() {
        let dir = std::env::temp_dir().join("decentlam_cfg_test_failclosed");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, r#"{"nodes": 8, "warp_drive": 1}"#).unwrap();
        let e = format!("{:#}", Config::load(&p).unwrap_err());
        assert!(
            e.contains("config: unknown config key `warp_drive`"),
            "error must name the key, got: {e}"
        );
        std::fs::write(&p, r#"{"out": "results.json"}"#).unwrap();
        let e = format!("{:#}", Config::load(&p).unwrap_err());
        assert!(
            e.contains("config: `out` is a CLI-only flag, not a config field"),
            "got: {e}"
        );
    }

    #[test]
    fn manifest_round_trips_defaults_and_composed_specs() {
        let mut cfg = Config::default();
        cfg.apply_kv("faults", "drop=0.1,seed=7").unwrap();
        cfg.apply_kv("codec", "topk,k=0.1").unwrap();
        cfg.apply_kv("schedule", "warmup-cosine").unwrap();
        cfg.seed = u64::MAX - 3; // beyond f64's exact range: string path
        for c in [Config::default(), cfg] {
            let m = c.to_manifest();
            let back = Config::from_manifest(&Cursor::root(&m, "config")).unwrap();
            assert_eq!(back, c, "manifest round trip:\n{}", m.to_pretty_string());
        }
    }

    #[test]
    fn manifest_spec_errors_carry_the_path() {
        let v = Value::parse(r#"{"faults": "drop=2"}"#).unwrap();
        let e = format!(
            "{:#}",
            Config::from_manifest(&Cursor::root(&v, "scenario.config")).unwrap_err()
        );
        assert_eq!(e, "scenario.config.faults: fault rate `drop=2` outside [0, 1]");
    }

    #[test]
    fn telemetry_is_cli_only_and_never_reaches_the_manifest() {
        let mut c = Config::default();
        c.apply_kv("telemetry", "out.jsonl").unwrap();
        assert_eq!(c.telemetry.as_deref(), Some("out.jsonl"));
        // Run identity is unchanged: the manifest of a telemetry-on
        // config is byte-identical to the telemetry-off one.
        let mut off = Config::default();
        assert_eq!(c.to_manifest().to_string(), off.to_manifest().to_string());
        c.apply_kv("telemetry", "").unwrap();
        assert!(c.telemetry.is_none(), "empty value clears the sink");
        // And manifests must not smuggle it back in.
        let v = Value::parse(r#"{"telemetry": "out.jsonl"}"#).unwrap();
        let e = format!("{:#}", Config::from_manifest(&Cursor::root(&v, "config")).unwrap_err());
        assert_eq!(e, "config: `telemetry` is a CLI-only flag, not a config field");
        off.apply_kv("telemetry", "x.jsonl").unwrap();
        assert_ne!(off, Config::default(), "field still participates in Eq");
    }

    #[test]
    fn telemetry_flush_suffix_parses_and_stays_cli_only() {
        let mut c = Config::default();
        assert_eq!(c.telemetry_flush, crate::telemetry::sink::DEFAULT_FLUSH_EVERY);
        c.apply_kv("telemetry", "out.jsonl,flush=1").unwrap();
        assert_eq!(c.telemetry.as_deref(), Some("out.jsonl"));
        assert_eq!(c.telemetry_flush, 1);
        c.apply_kv("telemetry", "out.jsonl,flush=0").unwrap();
        assert_eq!(c.telemetry_flush, 0);
        assert!(c.apply_kv("telemetry", "out.jsonl,flush=sometimes").is_err());
        // Flush cadence never reaches the manifest either.
        assert_eq!(c.to_manifest().to_string(), Config::default().to_manifest().to_string());
    }

    #[test]
    fn observability_cadences_are_cli_only_and_never_reach_the_manifest() {
        let mut c = Config::default();
        assert_eq!((c.metrics_every, c.profile_every), (0, 0));
        c.apply_kv("metrics", "every=5").unwrap();
        assert_eq!(c.metrics_every, 5);
        c.apply_kv("metrics", "3").unwrap();
        assert_eq!(c.metrics_every, 3);
        c.apply_kv("profile", "true").unwrap(); // bare --profile
        assert_eq!(c.profile_every, 1);
        c.apply_kv("profile", "every=10").unwrap();
        assert_eq!(c.profile_every, 10);
        c.apply_kv("profile", "false").unwrap();
        assert_eq!(c.profile_every, 0);
        assert!(c.apply_kv("metrics", "every=sometimes").is_err());
        // Run identity is unchanged with metrics/profiling on.
        c.apply_kv("metrics", "1").unwrap();
        c.apply_kv("profile", "1").unwrap();
        assert_eq!(c.to_manifest().to_string(), Config::default().to_manifest().to_string());
        // And manifests must not smuggle the cadences back in.
        for key in ["metrics", "profile"] {
            let v = Value::parse(&format!(r#"{{"{key}": "1"}}"#)).unwrap();
            let e =
                format!("{:#}", Config::from_manifest(&Cursor::root(&v, "config")).unwrap_err());
            assert_eq!(e, format!("config: `{key}` is a CLI-only flag, not a config field"));
        }
    }

    #[test]
    fn validate_pins_cross_field_invariants() {
        let mut c = Config::default();
        assert!(c.validate().is_ok());
        c.apply_kv("churn", "join=0.1").unwrap();
        c.apply_kv("topology", "one-peer-exp").unwrap();
        let e = c.validate().unwrap_err().to_string();
        assert_eq!(
            e,
            "--churn requires a static topology; `one-peer-exp` changes neighbors per step"
        );
        c.apply_kv("topology", "ring").unwrap();
        assert!(c.validate().is_ok());
        c.apply_kv("async", "tau=1").unwrap();
        let e = c.validate().unwrap_err().to_string();
        assert!(e.starts_with("--churn models synchronous rounds"), "got: {e}");
        c.apply_kv("churn", "").unwrap();
        c.apply_kv("optimizer", "slowmo").unwrap();
        let e = c.validate().unwrap_err().to_string();
        assert_eq!(
            e,
            "--async models pure gossip rounds; `slowmo`'s periodic all-reduce \
             is a global barrier (run pmsgd for the barrier baseline)"
        );
    }
}
