//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Substrate module (no `serde` in the offline registry). Consumes the
//! AOT `manifest.json` / `golden.json` and emits metrics/series files
//! for the experiment harness. Supports the full JSON grammar except
//! `\u` surrogate pairs (not needed by our producers).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects keep sorted keys (BTreeMap) so output is
/// deterministic — experiment outputs must diff cleanly across runs.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing bytes at {}", p.i);
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(x) => Ok(*x),
            _ => bail!("not a number"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("not a bool"),
        }
    }

    /// Strict unsigned integer: the number must be integral,
    /// non-negative, and below 2^53 (exactly representable in f64).
    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x.fract() != 0.0 || x < 0.0 || x >= 9007199254740992.0 {
            bail!("not an unsigned integer (got {x})");
        }
        Ok(x as u64)
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_f64().map(|x| x as f32))
            .collect()
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Value {
        Value::Arr(xs.iter().map(|&x| Value::Num(x as f64)).collect())
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation — the format of the checked-in
    /// scenario manifests, so `--pin` rewrites diff cleanly.
    pub fn to_pretty_string(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Value::Arr(v) if !v.is_empty() => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Value::Obj(m) if !m.is_empty() => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// A [`Value`] paired with its path from the document root, so every
/// error names the offending key (`scenario.config.faults: fault rate
/// \`drop=2\` outside [0, 1]`) instead of just the type.
///
/// Fail-closed manifest parsing is built on three Cursor habits:
/// navigate with [`Cursor::get`]/[`Cursor::opt`] (paths extend
/// automatically), read leaves with the typed accessors (errors are
/// prefixed with the path), and finish every object with
/// [`Cursor::deny_unknown`] so a typo'd field is a hard error naming
/// the field.
#[derive(Clone)]
pub struct Cursor<'a> {
    value: &'a Value,
    path: String,
}

impl<'a> Cursor<'a> {
    /// Root cursor; `name` is the path prefix for all errors
    /// (e.g. `"scenario"` or `"config"`).
    pub fn root(value: &'a Value, name: &str) -> Cursor<'a> {
        Cursor { value, path: name.to_string() }
    }

    pub fn path(&self) -> &str {
        &self.path
    }

    pub fn value(&self) -> &'a Value {
        self.value
    }

    fn err(&self, e: anyhow::Error) -> anyhow::Error {
        anyhow!("{}: {e}", self.path)
    }

    /// Required key; missing or non-object errors carry the path.
    pub fn get(&self, key: &str) -> Result<Cursor<'a>> {
        match self.value {
            Value::Obj(m) => m
                .get(key)
                .map(|v| Cursor { value: v, path: format!("{}.{key}", self.path) })
                .ok_or_else(|| anyhow!("{}: missing key `{key}`", self.path)),
            _ => bail!("{}: not an object (looking up `{key}`)", self.path),
        }
    }

    /// Optional key (`None` when absent or when the node is not an object).
    pub fn opt(&self, key: &str) -> Option<Cursor<'a>> {
        match self.value {
            Value::Obj(m) => m
                .get(key)
                .map(|v| Cursor { value: v, path: format!("{}.{key}", self.path) }),
            _ => None,
        }
    }

    /// Iterate an object's entries as `(key, child cursor)` pairs.
    pub fn entries(&self) -> Result<Vec<(&'a str, Cursor<'a>)>> {
        let m = self.value.as_obj().map_err(|e| self.err(e))?;
        Ok(m.iter()
            .map(|(k, v)| {
                (k.as_str(), Cursor { value: v, path: format!("{}.{k}", self.path) })
            })
            .collect())
    }

    /// Iterate an array's elements as indexed cursors (`path[i]`).
    pub fn items(&self) -> Result<Vec<Cursor<'a>>> {
        let v = self.value.as_arr().map_err(|e| self.err(e))?;
        Ok(v.iter()
            .enumerate()
            .map(|(i, x)| Cursor { value: x, path: format!("{}[{i}]", self.path) })
            .collect())
    }

    /// Fail-closed: error on any key outside `allowed`, naming both the
    /// stray field and the allowed set.
    pub fn deny_unknown(&self, allowed: &[&str]) -> Result<()> {
        let m = self.value.as_obj().map_err(|e| self.err(e))?;
        for k in m.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!(
                    "{}: unknown field `{k}` (allowed: {})",
                    self.path,
                    allowed.join(", ")
                );
            }
        }
        Ok(())
    }

    // ---- typed leaf accessors (path-prefixed errors) ---------------------

    pub fn as_f64(&self) -> Result<f64> {
        self.value.as_f64().map_err(|e| self.err(e))
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_u64()? as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        self.value.as_u64().map_err(|e| self.err(e))
    }

    pub fn as_bool(&self) -> Result<bool> {
        self.value.as_bool().map_err(|e| self.err(e))
    }

    pub fn as_str(&self) -> Result<&'a str> {
        self.value.as_str().map_err(|e| self.err(e))
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at byte {}", c as char, self.i)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Value::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number `{s}`: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode multi-byte UTF-8 starting at c.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let chunk = std::str::from_utf8(&self.b[start..start + width])?;
                        s.push_str(chunk);
                        self.i = start + width;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.eat(b'[')?;
        self.skip_ws();
        let mut v = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(v));
                }
                c => bail!("expected , or ] got `{}` at {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.eat(b'{')?;
        self.skip_ws();
        let mut m = BTreeMap::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(m));
                }
                c => bail!("expected , or }} got `{}` at {}", c as char, self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for s in ["null", "true", "false", "3", "-2.5", "\"hi\""] {
            let v = Value::parse(s).unwrap();
            assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a":[1,2,{"b":null}],"c":"x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parses_scientific_and_negative() {
        let v = Value::parse("[1e-3, -4.5E2, 0.0]").unwrap();
        let xs: Vec<f64> = v.as_arr().unwrap().iter().map(|x| x.as_f64().unwrap()).collect();
        assert_eq!(xs, vec![1e-3, -450.0, 0.0]);
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Value::parse(" {\n \"k\" :\t[ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_f32_vec().unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{\"a\":}").is_err());
        assert!(Value::parse("[1,2").is_err());
        assert!(Value::parse("12 34").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Value::parse("\"héllo → ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → ok");
    }

    #[test]
    fn writer_escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(Value::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn deterministic_object_order() {
        let v = Value::obj(vec![("zebra", Value::Num(1.0)), ("alpha", Value::Num(2.0))]);
        assert!(v.to_string().starts_with("{\"alpha\""));
    }

    #[test]
    fn strict_u64_and_bool() {
        assert_eq!(Value::Num(42.0).as_u64().unwrap(), 42);
        assert!(Value::Num(1.5).as_u64().is_err());
        assert!(Value::Num(-1.0).as_u64().is_err());
        assert!(Value::Num(9.1e15).as_u64().is_err());
        assert!(Value::Bool(true).as_bool().unwrap());
        assert!(Value::Num(1.0).as_bool().is_err());
    }

    #[test]
    fn cursor_paths_name_the_offending_key() {
        let v = Value::parse(r#"{"config":{"faults":{"drop":"x"},"lr":0.1}}"#).unwrap();
        let root = Cursor::root(&v, "scenario");
        let drop = root.get("config").unwrap().get("faults").unwrap().get("drop").unwrap();
        assert_eq!(drop.path(), "scenario.config.faults.drop");
        let e = drop.as_f64().unwrap_err().to_string();
        assert_eq!(e, "scenario.config.faults.drop: not a number");
        let e = root.get("config").unwrap().get("nope").unwrap_err().to_string();
        assert_eq!(e, "scenario.config: missing key `nope`");
    }

    #[test]
    fn cursor_denies_unknown_fields_by_name() {
        let v = Value::parse(r#"{"nodes":4,"typo_field":1}"#).unwrap();
        let c = Cursor::root(&v, "config");
        let e = c.deny_unknown(&["nodes", "lr"]).unwrap_err().to_string();
        assert_eq!(e, "config: unknown field `typo_field` (allowed: nodes, lr)");
        assert!(c.deny_unknown(&["nodes", "typo_field"]).is_ok());
    }

    #[test]
    fn cursor_entries_and_items_extend_paths() {
        let v = Value::parse(r#"{"a":[10,20]}"#).unwrap();
        let c = Cursor::root(&v, "m");
        let items = c.get("a").unwrap().items().unwrap();
        assert_eq!(items[1].path(), "m.a[1]");
        assert_eq!(items[1].as_u64().unwrap(), 20);
        let entries = c.entries().unwrap();
        assert_eq!(entries[0].0, "a");
        assert_eq!(entries[0].1.path(), "m.a");
    }

    #[test]
    fn pretty_print_round_trips_and_is_indented() {
        let v = Value::parse(r#"{"a":[1,2],"b":{"c":true},"d":[],"e":{}}"#).unwrap();
        let pretty = v.to_pretty_string();
        assert!(pretty.contains("\n  \"a\": [\n    1,\n    2\n  ]"));
        assert!(pretty.contains("\"d\": []"));
        assert!(pretty.contains("\"e\": {}"));
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    }
}
