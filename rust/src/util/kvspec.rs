//! The shared grammar of the comma-separated spec flags.
//!
//! `--faults drop=0.1,seed=7`, `--codec int8,ef=true`, `--async
//! tau=2,spread=4` and `--churn join=0.02,nmax=64` all speak the same
//! little language: comma-separated parts, each `key=value`, whitespace
//! tolerated everywhere, empty parts skipped. Before this module each
//! spec hand-rolled its own copy of that loop; the [`KvSpec`] trait
//! keeps ONE grammar implementation (`KvSpec::parse`) and leaves each
//! spec exactly three jobs: construct its defaults ([`KvSpec::begin`]),
//! accept one key ([`KvSpec::set_kv`]), and validate cross-key
//! invariants at the end ([`KvSpec::finish`]).
//!
//! Two grammar variations are expressed as associated consts so the
//! flags keep their historical shapes bit for bit:
//!
//! * [`KvSpec::BARE_TRUE`] — `--async` / `--churn` with no value reach
//!   the parser as the literal `"true"` (the CLI's bare-flag rule) and
//!   mean "enabled, all defaults";
//! * [`KvSpec::HAS_HEAD`] — `--codec` leads with a positional kind
//!   token (`int8,ef=true`), which `begin` receives before any
//!   `key=value` part.
//!
//! Every spec also serializes back through
//! [`KvSpec::to_spec_string`]: a canonical spec string that reparses to
//! an equal value (`parse(to_spec_string(s), 0) == s` — pinned by each
//! spec's round-trip tests). That closure property is what lets
//! `Config::to_manifest` / `Config::from_manifest` treat the spec
//! string as the manifest representation of the typed spec.
//!
//! Seed inheritance: every spec has a seed that defaults to the run
//! seed when the user omits `seed=`. The specs record that choice in a
//! `seed_from_run` flag set by their `set_kv`; config-boundary parsing
//! always passes `default_seed = 0`, and the trainer resolves the run
//! seed later via each spec's `with_run_seed`. `to_spec_string` only
//! emits `seed=` when it was explicit, so inherited seeds stay
//! inherited across a manifest round trip.

use anyhow::{bail, Result};

/// A spec type parsed from the shared `key=val,key=val` grammar.
pub trait KvSpec: Sized {
    /// Spec family name used in grammar errors
    /// (`"{NAME} spec entry `x` is not key=value"`).
    const NAME: &'static str;

    /// Accept the literal `"true"` (a bare CLI flag) as "all defaults".
    const BARE_TRUE: bool = false;

    /// The first comma part is a positional head token, not `key=value`.
    const HAS_HEAD: bool = false;

    /// Construct the spec before any `key=value` is applied. `head` is
    /// the positional leading token when [`KvSpec::HAS_HEAD`] is set
    /// (`None` = the spec string had no parts at all); specs without a
    /// head always receive `None`.
    fn begin(head: Option<&str>, default_seed: u64) -> Result<Self>;

    /// Apply one `key=value` part. `key` arrives trimmed; `value` is
    /// passed verbatim (trim it if the key wants that).
    fn set_kv(&mut self, key: &str, value: &str) -> Result<()>;

    /// Cross-key invariants, checked after the last part.
    fn finish(&self) -> Result<()> {
        Ok(())
    }

    /// Canonical spec string: reparses (with `default_seed = 0`) to an
    /// equal spec.
    fn to_spec_string(&self) -> String;

    /// THE grammar: split on commas, trim, skip empty parts, apply
    /// `key=value` parts in order (after the optional head token). A
    /// key given twice is a hard error — last-wins would silently
    /// discard half of `--faults drop=0.1,drop=0.2`, the opposite of
    /// the fail-closed manifest philosophy.
    fn parse(s: &str, default_seed: u64) -> Result<Self> {
        if Self::BARE_TRUE && s.trim() == "true" {
            return Self::begin(None, default_seed);
        }
        let mut parts = s.split(',').map(str::trim).filter(|p| !p.is_empty());
        let mut spec = if Self::HAS_HEAD {
            Self::begin(parts.next(), default_seed)?
        } else {
            Self::begin(None, default_seed)?
        };
        let mut seen: Vec<String> = Vec::new();
        for part in parts {
            let Some((k, v)) = part.split_once('=') else {
                bail!("{} spec entry `{part}` is not key=value", Self::NAME);
            };
            let k = k.trim();
            if seen.iter().any(|s| s == k) {
                bail!("{} spec key `{k}` given more than once", Self::NAME);
            }
            seen.push(k.to_string());
            spec.set_kv(k, v)?;
        }
        spec.finish()?;
        Ok(spec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A toy spec exercising the grammar plumbing in isolation.
    #[derive(Debug, PartialEq)]
    struct Toy {
        head: Option<String>,
        a: usize,
        seed: u64,
    }

    impl KvSpec for Toy {
        const NAME: &'static str = "toy";
        const BARE_TRUE: bool = true;
        const HAS_HEAD: bool = true;

        fn begin(head: Option<&str>, default_seed: u64) -> Result<Self> {
            Ok(Toy { head: head.map(str::to_string), a: 1, seed: default_seed })
        }

        fn set_kv(&mut self, key: &str, value: &str) -> Result<()> {
            match key {
                "a" => self.a = value.trim().parse()?,
                "seed" => self.seed = value.trim().parse()?,
                other => bail!("unknown toy key `{other}` (a|seed)"),
            }
            Ok(())
        }

        fn finish(&self) -> Result<()> {
            if self.a == 0 {
                bail!("toy a must be >= 1");
            }
            Ok(())
        }

        fn to_spec_string(&self) -> String {
            format!("{},a={}", self.head.as_deref().unwrap_or(""), self.a)
        }
    }

    #[test]
    fn grammar_splits_trims_and_skips_empty_parts() {
        let t = Toy::parse(" kind , a = 3 ,, seed=9 ", 1).unwrap();
        assert_eq!(t.head.as_deref(), Some("kind"));
        assert_eq!(t.a, 3);
        assert_eq!(t.seed, 9);
    }

    #[test]
    fn bare_true_is_all_defaults() {
        let t = Toy::parse("true", 7).unwrap();
        assert_eq!(t, Toy { head: None, a: 1, seed: 7 });
    }

    #[test]
    fn errors_name_the_spec_family() {
        let e = Toy::parse("kind,notkv", 0).unwrap_err().to_string();
        assert_eq!(e, "toy spec entry `notkv` is not key=value");
        assert!(Toy::parse("kind,b=1", 0).is_err());
    }

    #[test]
    fn finish_validates_cross_key_invariants() {
        assert!(Toy::parse("kind,a=0", 0).is_err());
        assert!(Toy::parse("kind,a=2", 0).is_ok());
    }

    #[test]
    fn duplicate_keys_are_hard_errors_naming_the_key() {
        let e = Toy::parse("kind,a=1,a=2", 0).unwrap_err().to_string();
        assert_eq!(e, "toy spec key `a` given more than once");
        // Whitespace-padded repeats of the same key still collide …
        let e = Toy::parse("kind,seed=1, seed =2", 0).unwrap_err().to_string();
        assert_eq!(e, "toy spec key `seed` given more than once");
        // … while distinct keys stay fine.
        assert!(Toy::parse("kind,a=2,seed=5", 0).is_ok());
    }
}
