//! Flat-vector and small dense-matrix math.
//!
//! The decentralized update rules operate on flat `f32` parameter
//! vectors (mirroring the Layer-2 flat-theta convention); the topology
//! analysis needs a symmetric eigensolver for the mixing matrix `W`
//! (n x n with n = node count, so a classic cyclic Jacobi is plenty).

/// y += a * x  (the hot op of every optimizer update).
#[inline]
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = a * x + b * y.
#[inline]
pub fn axpby(y: &mut [f32], a: f32, x: &[f32], b: f32) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi = a * xi + b * *yi;
    }
}

/// Scale in place.
#[inline]
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Dot product (f64 accumulator for stability over millions of params).
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared distance between two vectors.
pub fn dist2(x: &[f32], y: &[f32]) -> f64 {
    x.iter()
        .zip(y)
        .map(|(a, b)| {
            let d = (*a - *b) as f64;
            d * d
        })
        .sum()
}

/// Sequential left-to-right f64 sum — the one home for order-sensitive
/// float reductions outside this module (determinism rule D05,
/// DESIGN.md §12: reduction order is part of the bitwise-replay
/// contract, so it lives here and nowhere else).
pub fn sum_f64(xs: impl IntoIterator<Item = f64>) -> f64 {
    let mut acc = 0.0f64;
    for x in xs {
        acc += x;
    }
    acc
}

/// Mean of a slice via [`sum_f64`] (NaN on empty input).
pub fn mean_f64(xs: &[f64]) -> f64 {
    sum_f64(xs.iter().copied()) / xs.len() as f64
}

/// Euclidean norm of an f64 slice via [`sum_f64`].
pub fn norm2_f64(x: &[f64]) -> f64 {
    sum_f64(x.iter().map(|v| v * v)).sqrt()
}

/// out = Σ_t w_t · x_t, fusing terms pairwise so the destination is
/// traversed ~(1 + k/2) times instead of (k+1) — the gossip hot path
/// (`optim::partial_average_all`) is memory-bound and this halves its
/// traffic for typical degrees (EXPERIMENTS.md §Perf).
pub fn weighted_sum_into(out: &mut [f32], terms: &[(f32, &[f32])]) {
    let d = out.len();
    match terms {
        [] => out.iter_mut().for_each(|v| *v = 0.0),
        [(w0, x0), rest @ ..] => {
            debug_assert_eq!(x0.len(), d);
            for (o, &x) in out.iter_mut().zip(*x0) {
                *o = w0 * x;
            }
            let mut it = rest.chunks_exact(2);
            for pair in &mut it {
                let (wa, xa) = pair[0];
                let (wb, xb) = pair[1];
                debug_assert_eq!(xa.len(), d);
                debug_assert_eq!(xb.len(), d);
                for ((o, &a), &b) in out.iter_mut().zip(xa).zip(xb) {
                    *o += wa * a + wb * b;
                }
            }
            if let [(w, x)] = it.remainder() {
                axpy(out, *w, x);
            }
        }
    }
}

/// Elementwise mean of many equal-length vectors.
pub fn mean_of(vectors: &[&[f32]]) -> Vec<f32> {
    let n = vectors.len();
    assert!(n > 0);
    let d = vectors[0].len();
    let mut out = vec![0.0f32; d];
    for v in vectors {
        axpy(&mut out, 1.0, v);
    }
    scale(&mut out, 1.0 / n as f32);
    out
}

/// Dense row-major symmetric matrix of f64 (sized by node count).
#[derive(Clone, Debug)]
pub struct SymMatrix {
    pub n: usize,
    pub a: Vec<f64>,
}

impl SymMatrix {
    pub fn zeros(n: usize) -> Self {
        Self { n, a: vec![0.0; n * n] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.a[i * self.n + j] = v;
        self.a[j * self.n + i] = v;
    }

    /// Max absolute asymmetry (diagnostic).
    pub fn asymmetry(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.n {
                m = m.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        m
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let n = self.n;
        let mut y = vec![0.0; n];
        for i in 0..n {
            let row = &self.a[i * n..(i + 1) * n];
            y[i] = row.iter().zip(x).map(|(a, b)| a * b).sum();
        }
        y
    }

    /// All eigenvalues via cyclic Jacobi (symmetric input), ascending.
    pub fn eigenvalues(&self) -> Vec<f64> {
        let n = self.n;
        let mut a = self.a.clone();
        let idx = |i: usize, j: usize| i * n + j;
        for _sweep in 0..100 {
            let mut off = 0.0;
            for i in 0..n {
                for j in (i + 1)..n {
                    off += a[idx(i, j)] * a[idx(i, j)];
                }
            }
            if off < 1e-24 {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = a[idx(p, q)];
                    if apq.abs() < 1e-18 {
                        continue;
                    }
                    let app = a[idx(p, p)];
                    let aqq = a[idx(q, q)];
                    let theta = (aqq - app) / (2.0 * apq);
                    let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    for k in 0..n {
                        let akp = a[idx(k, p)];
                        let akq = a[idx(k, q)];
                        a[idx(k, p)] = c * akp - s * akq;
                        a[idx(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[idx(p, k)];
                        let aqk = a[idx(q, k)];
                        a[idx(p, k)] = c * apk - s * aqk;
                        a[idx(q, k)] = s * apk + c * aqk;
                    }
                }
            }
        }
        let mut ev: Vec<f64> = (0..n).map(|i| a[idx(i, i)]).collect();
        ev.sort_by(|x, y| x.partial_cmp(y).unwrap());
        ev
    }
}

/// Least-squares slope of y over x (used by Table 2 to fit the empirical
/// bias-scaling exponents in log–log space).
pub fn linfit_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (xi, yi) in x.iter().zip(y) {
        num += (xi - mx) * (yi - my);
        den += (xi - mx) * (xi - mx);
    }
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_axpby_scale() {
        let mut y = vec![1.0, 2.0, 3.0];
        axpy(&mut y, 2.0, &[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![3.0, 4.0, 5.0]);
        axpby(&mut y, 1.0, &[1.0, 0.0, 0.0], 0.5);
        assert_eq!(y, vec![2.5, 2.0, 2.5]);
        scale(&mut y, 2.0);
        assert_eq!(y, vec![5.0, 4.0, 5.0]);
    }

    #[test]
    fn norms_and_distances() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
        assert!((dist2(&[1.0, 1.0], &[0.0, 0.0]) - 2.0).abs() < 1e-12);
        assert!((dot(&[1.0, 2.0], &[3.0, 4.0]) - 11.0).abs() < 1e-12);
    }

    #[test]
    fn canonical_f64_reductions() {
        assert_eq!(sum_f64([1.0, 2.0, 3.0]), 6.0);
        assert_eq!(mean_f64(&[1.0, 2.0, 3.0]), 2.0);
        assert!((norm2_f64(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
        // Bitwise left-to-right, exactly like a sequential loop.
        let xs = [1e16, 1.0, -1e16];
        let mut acc = 0.0f64;
        for x in xs {
            acc += x;
        }
        assert_eq!(sum_f64(xs).to_bits(), acc.to_bits());
    }

    #[test]
    fn weighted_sum_matches_axpy_reference() {
        let mut rng = crate::util::rng::Pcg64::seeded(3);
        for k in 0..7 {
            let d = 37;
            let xs: Vec<Vec<f32>> = (0..k)
                .map(|_| {
                    let mut v = vec![0.0f32; d];
                    rng.normal_fill(&mut v, 1.0);
                    v
                })
                .collect();
            let ws: Vec<f32> = (0..k).map(|_| rng.f32() - 0.3).collect();
            let terms: Vec<(f32, &[f32])> =
                ws.iter().cloned().zip(xs.iter().map(|v| v.as_slice())).collect();
            let mut got = vec![7.0f32; d]; // junk: must be overwritten
            weighted_sum_into(&mut got, &terms);
            let mut want = vec![0.0f32; d];
            for (w, x) in &terms {
                axpy(&mut want, *w, x);
            }
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-5, "k={k}");
            }
        }
    }

    #[test]
    fn mean_of_vectors() {
        let a = vec![0.0f32, 2.0];
        let b = vec![2.0f32, 4.0];
        assert_eq!(mean_of(&[&a, &b]), vec![1.0, 3.0]);
    }

    #[test]
    fn jacobi_on_known_matrix() {
        // [[2,1],[1,2]] has eigenvalues 1, 3.
        let mut m = SymMatrix::zeros(2);
        m.set(0, 0, 2.0);
        m.set(1, 1, 2.0);
        m.set(0, 1, 1.0);
        let ev = m.eigenvalues();
        assert!((ev[0] - 1.0).abs() < 1e-10);
        assert!((ev[1] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_trace_preserved() {
        let n = 8;
        let mut m = SymMatrix::zeros(n);
        let mut seed = 1u64;
        for i in 0..n {
            for j in i..n {
                seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
                let v = ((seed >> 33) as f64 / (1u64 << 31) as f64) - 0.5;
                m.set(i, j, v);
            }
        }
        let trace: f64 = (0..n).map(|i| m.get(i, i)).sum();
        let ev_sum: f64 = m.eigenvalues().iter().sum();
        assert!((trace - ev_sum).abs() < 1e-8);
    }

    #[test]
    fn slope_of_exact_line() {
        let x = vec![0.0, 1.0, 2.0, 3.0];
        let y = vec![1.0, 3.0, 5.0, 7.0];
        assert!((linfit_slope(&x, &y) - 2.0).abs() < 1e-12);
    }
}
