//! Substrate utilities built in-tree (the offline registry has no `rand`,
//! `serde`, `clap`, or `criterion` — see DESIGN.md §2).

pub mod bench;
pub mod cli;
pub mod config;
pub mod json;
pub mod kvspec;
pub mod math;
pub mod rng;
pub mod sha256;
pub mod table;
