//! Deterministic pseudo-random numbers: PCG64 core + the sampling
//! routines the framework needs (normal, Dirichlet, shuffling).
//!
//! Substrate module: the offline registry only ships `rand_core`, so the
//! generator and every distribution are implemented here. Determinism is
//! load-bearing — experiment tables must reproduce bit-identically for a
//! given seed, and time-varying topologies (bipartite random match) rely
//! on all nodes drawing the same permutation from a shared seed.

/// PCG-XSL-RR 128/64 (Melissa O'Neill's PCG64): 128-bit LCG state,
/// 64-bit xor-shift + random-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed the generator; `stream` selects an independent sequence
    /// (nodes use their rank so shards never correlate).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.next_u64();
        rng
    }

    /// Convenience constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// THE counter-keyed stream constructor — the shared discipline of
    /// every seeded schedule in the framework (fault plans, churn
    /// plans, stochastic-rounding streams, clock jitter): mix `step`
    /// into the seed with a golden-ratio multiply, domain-separate
    /// with `tag`, then select `entity`'s independent stream. Draws
    /// are replayable and iteration-order free by construction. All
    /// schedule call sites go through this one helper so the
    /// disciplines can never silently fork.
    pub fn counter_keyed(seed: u64, tag: u64, step: u64, entity: u64) -> Self {
        let mixed = seed.wrapping_add(step.wrapping_mul(0x9e37_79b9_7f4a_7c15)) ^ tag;
        Self::new(mixed, entity)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // 128-bit multiply keeps bias < 2^-64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (pairs cached).
    pub fn normal(&mut self) -> f64 {
        // Draw two uniforms per call, discard the second half: simpler
        // state (no cache) and the hot loops batch with normal_fill.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fill a slice with N(0, sigma^2) f32 samples.
    pub fn normal_fill(&mut self, out: &mut [f32], sigma: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * sigma;
        }
    }

    /// Gamma(alpha, 1) via Marsaglia–Tsang, with the alpha<1 boost.
    pub fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha * 1_k): the heterogeneity knob for data partitions
    /// (small alpha -> near-disjoint label distributions across nodes).
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in g.iter_mut() {
            *v /= s;
        }
        g
    }

    /// Fisher–Yates in-place shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Raw generator state `[state_lo, state_hi, inc_lo, inc_hi]` for
    /// checkpointing (DESIGN.md §9); restore with
    /// [`Pcg64::from_raw_state`] to continue the exact stream.
    pub fn raw_state(&self) -> [u64; 4] {
        [
            self.state as u64,
            (self.state >> 64) as u64,
            self.inc as u64,
            (self.inc >> 64) as u64,
        ]
    }

    /// Rebuild a generator from [`Pcg64::raw_state`] — the next draw is
    /// bit-identical to what the exported generator would have produced.
    pub fn from_raw_state(raw: [u64; 4]) -> Pcg64 {
        Pcg64 {
            state: ((raw[1] as u128) << 64) | raw[0] as u128,
            inc: ((raw[3] as u128) << 64) | raw[2] as u128,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = Pcg64::new(42, 1);
        let mut b = Pcg64::new(42, 2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Pcg64::seeded(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.f64();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(9);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_alpha() {
        let mut r = Pcg64::seeded(11);
        for &alpha in &[0.3, 1.0, 4.5] {
            let n = 20_000;
            let mean: f64 = (0..n).map(|_| r.gamma(alpha)).sum::<f64>() / n as f64;
            assert!(
                (mean - alpha).abs() < 0.1 * alpha.max(0.5),
                "alpha={alpha} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Pcg64::seeded(13);
        for &alpha in &[0.1, 1.0, 10.0] {
            let p = r.dirichlet(alpha, 10);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_alpha_controls_concentration() {
        let mut r = Pcg64::seeded(17);
        let maxes_small: f64 = (0..200)
            .map(|_| r.dirichlet(0.1, 8).into_iter().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        let maxes_big: f64 = (0..200)
            .map(|_| r.dirichlet(50.0, 8).into_iter().fold(0.0, f64::max))
            .sum::<f64>()
            / 200.0;
        assert!(maxes_small > maxes_big + 0.2, "{maxes_small} vs {maxes_big}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::seeded(19);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn raw_state_roundtrip_continues_stream() {
        let mut a = Pcg64::new(7, 123);
        for _ in 0..17 {
            a.next_u64();
        }
        let mut b = Pcg64::from_raw_state(a.raw_state());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg64::seeded(23);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }
}
