//! Paper-style table rendering for the experiment harness: the benches
//! print the same rows/columns the paper's tables report.

/// A simple column-aligned text table (also emits CSV and Markdown).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Aligned plain-text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let line = |cells: &[String], w: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:<width$}", c, width = w[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&line(&self.headers, &w));
        out.push_str(&format!(
            "{}\n",
            w.iter()
                .map(|n| "-".repeat(*n))
                .collect::<Vec<_>>()
                .join("  ")
        ));
        for r in &self.rows {
            out.push_str(&line(r, &w));
        }
        out
    }

    /// CSV rendering (for plotting the figure series).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        out.push_str(&self.headers.join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.join(","));
            out.push('\n');
        }
        out
    }

    /// Markdown rendering (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("| {} |\n", self.headers.join(" | ")));
        out.push_str(&format!(
            "|{}|\n",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        ));
        for r in &self.rows {
            out.push_str(&format!("| {} |\n", r.join(" | ")));
        }
        out
    }
}

/// Format an accuracy fraction as the paper prints it (e.g. 76.43).
pub fn pct(x: f64) -> String {
    format!("{:.2}", 100.0 * x)
}

/// Format a float with fixed significant digits for table cells.
pub fn sig(x: f64, digits: usize) -> String {
    if x == 0.0 || !x.is_finite() {
        return format!("{x}");
    }
    let mag = x.abs().log10().floor() as i32;
    let dec = (digits as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", dec, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row(vec!["DecentLaM".into(), "76.43".into()]);
        t.row(vec!["PmSGD".into(), "75.27".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("DecentLaM  76.43"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn csv_and_markdown() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
        assert!(t.to_markdown().contains("| 1 | 2 |"));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(pct(0.7643), "76.43");
        assert_eq!(sig(0.0012345, 3), "0.00123");
        assert_eq!(sig(123.45, 3), "123");
    }
}
