//! Property tests for the discrete-event asynchronous gossip runtime
//! (DESIGN.md §8): the three pinned invariants of the clock layer —
//!
//! 1. async(uniform speeds, zero jitter, τ = 0) is **bitwise equal** to
//!    the synchronous `Trainer`;
//! 2. the event queue and the realized schedule are replay-identical
//!    across thread counts and shuffled insertion orders;
//! 3. simulated wall time matches the closed-form `per_iter_comm_s`
//!    prediction within 1% on a homogeneous ring —
//!
//! plus staleness-bound, composition (faults × codec × async) and
//! multi-payload checks.

use decentlam::comm::{CommCost, CommStats, PayloadBytes};
use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::{mlp, Workload};
use decentlam::optim::CommPattern;
use decentlam::sim::clock::{simulate_barrier, simulate_gossip, AsyncSpec, Event, EventQueue, Phase};
use decentlam::topology::{Kind, SparseWeights, Topology};
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::rng::Pcg64;

fn workload(nodes: usize, seed: u64) -> Workload {
    let data = ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 128,
        eval_samples: 128,
        dirichlet_alpha: 0.3,
        seed,
        ..Default::default()
    });
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 16, seed)
}

fn cfg(optimizer: &str, nodes: usize, steps: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.total_batch = 32 * nodes;
    cfg.micro_batch = 16;
    cfg.lr = 0.03;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg.seed = 5;
    cfg
}

// ---- invariant 1: uniform + tau=0 is bitwise synchronous ------------

#[test]
fn async_uniform_tau0_bitwise_equals_sync_across_optimizers() {
    // Every gossip optimizer, including the two-payload da-dmsgd, on a
    // regular AND an irregular topology.
    for topology in ["ring", "star"] {
        for opt in ["dsgd", "dmsgd", "decentlam", "qg-dmsgd", "awc-dmsgd", "d2-dmsgd", "da-dmsgd"]
        {
            let run = |asynch: &str| {
                let mut c = cfg(opt, 6, 20);
                c.topology = topology.into();
                c.apply_kv("async", asynch).unwrap();
                Trainer::new(c, workload(6, 5)).unwrap().run().losses
            };
            assert_eq!(
                run(""),
                run("tau=0,spread=1,jitter=0"),
                "{opt} on {topology}: async(uniform, tau=0) must be bitwise synchronous"
            );
        }
    }
}

#[test]
fn async_uniform_regular_graph_is_fresh_even_with_positive_tau() {
    // Uniform clocks on a regular graph run in lockstep: τ > 0 gives
    // slack nothing uses, so the run stays bitwise synchronous.
    let run = |asynch: &str| {
        let mut c = cfg("decentlam", 8, 20);
        c.apply_kv("async", asynch).unwrap();
        Trainer::new(c, workload(8, 5)).unwrap().run().losses
    };
    assert_eq!(run(""), run("tau=2,spread=1,jitter=0"));
}

// ---- invariant 2: replay identity -----------------------------------

#[test]
fn event_queue_pop_order_is_insertion_order_free() {
    // Build a deterministic event population (each node once — the
    // queue's uniqueness domain), pop in every shuffled insertion
    // order: the sequence must be identical.
    let mut events = Vec::new();
    for node in 0..257u32 {
        events.push(Event {
            // Quantized times: many exact ties, so the (phase, node)
            // tiebreak actually decides the order.
            time: (node % 7) as f64 * 0.5,
            phase: if node % 3 == 0 { Phase::Publish } else { Phase::Gather },
            node,
        });
    }
    let reference: Vec<Event> = {
        let mut q = EventQueue::new();
        for &e in &events {
            q.push(e);
        }
        std::iter::from_fn(move || q.pop()).collect()
    };
    assert_eq!(reference.len(), events.len());
    for shuffle_seed in [1u64, 7, 99] {
        let mut shuffled = events.clone();
        Pcg64::seeded(shuffle_seed).shuffle(&mut shuffled);
        let mut q = EventQueue::new();
        for &e in &shuffled {
            q.push(e);
        }
        let got: Vec<Event> = std::iter::from_fn(move || q.pop()).collect();
        assert_eq!(got, reference, "pop order changed under shuffle seed {shuffle_seed}");
    }
    // And the order is the documented (time, phase, node) total order.
    for w in reference.windows(2) {
        assert!(w[0] <= w[1]);
    }
}

#[test]
fn schedule_and_training_replay_across_thread_counts() {
    let sw = SparseWeights::metropolis_hastings(&Topology::build(Kind::Ring, 8));
    let spec = AsyncSpec::parse("tau=2,spread=6,jitter=0.3,seed=9", 0).unwrap();
    let a = simulate_gossip(&spec, &sw, 4096.0, 1, 50);
    let b = simulate_gossip(&spec, &sw, 4096.0, 1, 50);
    assert_eq!(a, b, "schedule must replay identically");

    let run = |threads: usize| {
        let mut c = cfg("decentlam", 8, 30);
        c.threads = threads;
        c.apply_kv("async", "tau=2,spread=6,jitter=0.3,seed=9").unwrap();
        Trainer::new(c, workload(8, 5)).unwrap().run().losses
    };
    let serial = run(1);
    assert_eq!(serial, run(0), "async training must be thread-count free");
    assert_eq!(serial, run(3));
    assert!(serial.iter().all(|l| l.is_finite()));
}

// ---- invariant 3: simulated time vs the closed-form cost model ------

#[test]
fn simulated_wall_time_within_1pct_of_formula_on_homogeneous_ring() {
    let n = 16;
    let sw = SparseWeights::metropolis_hastings(&Topology::build(Kind::Ring, n));
    let stats = CommStats::of_engine(&sw);
    let bytes = 25.5e6 * 4.0; // the Fig. 6 ResNet-50 payload
    let spec = AsyncSpec::parse("tau=1,spread=1,jitter=0,compute=12", 0).unwrap();
    let steps = 20;
    let cost = CommCost::new(spec.link());
    let payload = PayloadBytes::uniform(bytes);

    // Gossip: per-iteration event time vs compute + neighbor exchange.
    let sched = simulate_gossip(&spec, &sw, bytes, 1, steps);
    let sim = sched.report().makespan_s / steps as f64;
    let formula =
        12.0e-3 + cost.per_iter_comm_s(CommPattern::Neighbor { payloads: 1 }, &stats, payload);
    let rel = (sim - formula).abs() / formula;
    assert!(rel < 0.01, "gossip: sim {sim} vs formula {formula} ({:.4}% off)", 100.0 * rel);

    // All-reduce barrier: per-iteration vs compute + ring all-reduce.
    let ar = cost.allreduce_s(n, bytes);
    let (cum, _) = simulate_barrier(&spec, n, ar, steps);
    let sim_ar = cum[steps - 1] / steps as f64;
    let formula_ar = 12.0e-3 + ar;
    let rel_ar = (sim_ar - formula_ar).abs() / formula_ar;
    assert!(rel_ar < 0.01, "barrier: sim {sim_ar} vs formula {formula_ar}");
}

// ---- staleness semantics --------------------------------------------

#[test]
fn staleness_is_bounded_by_tau_and_history() {
    let sw = SparseWeights::metropolis_hastings(&Topology::build(Kind::Ring, 12));
    for tau in [0usize, 1, 3] {
        let spec = AsyncSpec::parse(&format!("tau={tau},spread=8,jitter=0.4,seed=3"), 0).unwrap();
        let sched = simulate_gossip(&spec, &sw, 4096.0, 1, 50);
        let r = sched.report();
        assert!(
            r.max_staleness as usize <= tau,
            "tau={tau}: delivered age {} beyond the window",
            r.max_staleness
        );
        if tau == 0 {
            assert_eq!(r.mean_staleness, 0.0, "tau=0 must be barrier-exact");
            assert!(r.total_wait_s > 0.0, "tau=0 under an 8x spread must wait");
        } else {
            assert!(r.max_staleness >= 1, "tau={tau}: an 8x spread never went stale");
        }
    }
}

#[test]
fn async_run_descends_and_reports_staleness() {
    let mut c = cfg("decentlam", 8, 60);
    c.lr = 0.02;
    c.apply_kv("async", "tau=2,spread=6,jitter=0.2,seed=4").unwrap();
    let mut t = Trainer::new(c, workload(8, 5)).unwrap();
    let report = t.run();
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let first = report.losses[..5].iter().sum::<f64>() / 5.0;
    let last = report.losses[report.losses.len() - 5..].iter().sum::<f64>() / 5.0;
    assert!(last < first, "no descent under bounded staleness ({first} -> {last})");
    let a = t.async_report().unwrap();
    assert_eq!(a.step_done_s.len(), 60);
    assert!(a.step_done_s.windows(2).all(|w| w[0] < w[1]), "time must advance");
    assert!(a.max_staleness >= 1 && a.max_staleness <= 2);
    assert!(a.stale_fraction > 0.0 && a.stale_fraction < 1.0);
    let stats = t.fault_stats().expect("async gossip runs carry engine stats");
    assert!(stats.async_stale_messages > 0);
    assert_eq!(stats.masked_edges, 0, "staleness must not mask edges");
}

// ---- composition ------------------------------------------------------

#[test]
fn async_composes_with_faults_and_codecs_deterministically() {
    let run = || {
        let mut c = cfg("decentlam", 8, 40);
        c.lr = 0.02;
        c.apply_kv("async", "tau=2,spread=4,jitter=0.2,seed=6").unwrap();
        c.apply_kv("faults", "drop=0.1,straggle=0.15,seed=8").unwrap();
        c.apply_kv("codec", "int8,ef=true,seed=2").unwrap();
        let mut t = Trainer::new(c, workload(8, 5)).unwrap();
        let losses = t.run().losses;
        let stats = *t.fault_stats().unwrap();
        (losses, stats)
    };
    let (a, sa) = run();
    let (b, sb) = run();
    assert_eq!(a, b, "faults x codec x async must replay byte-identically");
    assert_eq!(sa, sb);
    assert!(a.iter().all(|l| l.is_finite()));
    assert!(sa.masked_edges > 0, "drop=0.1 never masked an edge");
    assert!(
        sa.stale_messages + sa.async_stale_messages > 0,
        "neither stragglers nor the clock spread ever delivered stale"
    );
}

#[test]
fn fault_stales_replay_even_at_tau_zero() {
    // tau=0 means no CLOCK staleness, but straggle faults must still
    // replay age-1 payloads from the ring history (regression: the ring
    // depth covers fault stales even when the async window itself is 0
    // — without that, straggle/stale faults under `--async tau=0` were
    // silent no-ops: no replay AND no masking fallback).
    let run = |faults: &str| {
        let mut c = cfg("decentlam", 8, 30);
        c.lr = 0.02;
        c.apply_kv("async", "tau=0,spread=4,jitter=0.2,seed=6").unwrap();
        c.apply_kv("faults", faults).unwrap();
        let mut t = Trainer::new(c, workload(8, 5)).unwrap();
        let losses = t.run().losses;
        let stats = *t.fault_stats().unwrap();
        (losses, stats)
    };
    let (a, sa) = run("straggle=0.3,seed=8");
    assert_eq!(a, run("straggle=0.3,seed=8").0, "must replay identically");
    assert!(a.iter().all(|l| l.is_finite()));
    assert!(sa.stale_messages > 0, "straggle=0.3 never delivered a stale replay at tau=0");
    assert_eq!(sa.async_stale_messages, 0, "a tau=0 window never clock-stales");
    // The replays actually reach training: different from fault-free.
    let (b, sb) = run("");
    assert_eq!(sb.stale_messages, 0);
    assert_ne!(a, b, "stale replay had no effect on training");
}

#[test]
fn multi_payload_async_replays_per_slot_history() {
    // da-dmsgd's two exchanges per round get their own ring caches: the
    // run must be finite, deterministic and thread-count free, with
    // staleness realized and no masking downgrade.
    let run = |threads: usize| {
        let mut c = cfg("da-dmsgd", 8, 30);
        c.lr = 0.02;
        c.threads = threads;
        c.apply_kv("async", "tau=2,spread=6,jitter=0.3,seed=7").unwrap();
        let mut t = Trainer::new(c, workload(8, 5)).unwrap();
        let losses = t.run().losses;
        let stats = *t.fault_stats().unwrap();
        (losses, stats)
    };
    let (a, sa) = run(0);
    assert_eq!(a, run(0).0);
    assert_eq!(a, run(1).0, "parallel != serial for multi-payload async");
    assert!(a.iter().all(|l| l.is_finite()));
    assert!(sa.async_stale_messages > 0);
    assert_eq!(sa.masked_edges, 0);
}

// ---- guard rails ------------------------------------------------------

#[test]
fn async_guard_rails_reject_unsupported_shapes() {
    // Time-varying topologies have no static event graph.
    let mut c = cfg("decentlam", 6, 5);
    c.topology = "one-peer-exp".into();
    c.apply_kv("async", "tau=1").unwrap();
    assert!(Trainer::new(c, workload(6, 5)).is_err());
    // SlowMo's periodic all-reduce is a global barrier.
    let mut c = cfg("slowmo", 6, 5);
    c.apply_kv("async", "tau=1").unwrap();
    assert!(Trainer::new(c, workload(6, 5)).is_err());
    // PmSGD runs as the barrier baseline: report only, no staleness.
    let mut c = cfg("pmsgd", 6, 8);
    c.apply_kv("async", "tau=2,spread=4,jitter=0.1").unwrap();
    let mut t = Trainer::new(c, workload(6, 5)).unwrap();
    let r = t.run();
    assert!(r.losses.iter().all(|l| l.is_finite()));
    assert!(t.fault_stats().is_none());
    let a = t.async_report().unwrap();
    assert_eq!(a.max_staleness, 0);
    assert_eq!(a.step_done_s.len(), 8);
    assert!(a.total_wait_s > 0.0);
}
