//! Dynamic pins for the determinism contract the lint pass (DESIGN.md
//! §12, `cargo run -p xtask -- lint`) enforces statically:
//!
//! 1. the synthetic-data path is rerun-byte-identical — two generates
//!    from one spec produce bitwise-equal shards and batch streams
//!    (regression cover for the D01 `HashSet` fix in `data/synth`);
//! 2. wall-clock readings (rule D02) stay on the report side: they feed
//!    `TrainReport::{grad,update}_seconds` only, and never reach the
//!    manifest, the scenario digest inputs, or the telemetry stream —
//!    all of which must be byte-identical across identical runs on a
//!    machine whose wall clock obviously is not.

use std::path::PathBuf;

use decentlam::coordinator::{TrainReport, Trainer};
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::{mlp, Workload};
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::sha256::Sha256;

fn spec(seed: u64) -> SynthSpec {
    SynthSpec {
        nodes: 4,
        samples_per_node: 96,
        eval_samples: 128,
        dirichlet_alpha: 0.3,
        seed,
        ..Default::default()
    }
}

fn workload(seed: u64) -> Workload {
    let data = ClassificationData::generate(&spec(seed));
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 16, seed)
}

fn cfg() -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = "decentlam".into();
    cfg.nodes = 4;
    cfg.steps = 6;
    cfg.total_batch = 64;
    cfg.micro_batch = 16;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg.eval_every = 3;
    cfg.threads = 1;
    cfg.seed = 7;
    cfg
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("decentlam_determinism_{}_{name}", std::process::id()))
}

#[test]
fn synth_generation_is_rerun_byte_identical() {
    let a = ClassificationData::generate(&spec(11));
    let b = ClassificationData::generate(&spec(11));
    assert_eq!(a.shards.len(), b.shards.len());
    let bits = |xs: &[f32]| xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    for (sa, sb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(bits(&sa.x), bits(&sb.x), "shard features drifted between reruns");
        assert_eq!(sa.y, sb.y, "shard labels drifted between reruns");
    }
    assert_eq!(bits(&a.eval_x), bits(&b.eval_x), "eval features drifted between reruns");
    assert_eq!(a.eval_y, b.eval_y, "eval labels drifted between reruns");
}

#[test]
fn synth_batch_stream_is_rerun_byte_identical() {
    let mut a = ClassificationData::generate(&spec(3));
    let mut b = ClassificationData::generate(&spec(3));
    let d = a.shards[0].input_dim;
    let (mut ax, mut ay) = (vec![0.0f32; 4 * d], vec![0i32; 4]);
    let (mut bx, mut by) = (vec![0.0f32; 4 * d], vec![0i32; 4]);
    for round in 0..12 {
        a.shards[0].next_batch(&mut ax, &mut ay);
        b.shards[0].next_batch(&mut bx, &mut by);
        let abits: Vec<u32> = ax.iter().map(|v| v.to_bits()).collect();
        let bbits: Vec<u32> = bx.iter().map(|v| v.to_bits()).collect();
        assert_eq!(abits, bbits, "batch {round}: feature bytes drifted");
        assert_eq!(ay, by, "batch {round}: labels drifted");
    }
}

/// The digest recipe scenario pins use (`scenario/runner.rs`): manifest
/// bytes + per-step loss bits + final metric bits. Everything wall time
/// could pollute, nothing it may feed.
fn replay_digest(report: &TrainReport, eval_loss: Option<f64>) -> String {
    let mut h = Sha256::new();
    h.update(report.manifest.as_bytes());
    for l in &report.losses {
        h.update(&l.to_bits().to_be_bytes());
    }
    h.update(&report.final_accuracy.to_bits().to_be_bytes());
    h.update(&report.final_consensus.to_bits().to_be_bytes());
    if let Some(el) = eval_loss {
        h.update(&el.to_bits().to_be_bytes());
    }
    h.finish_hex()
}

#[test]
fn wall_clock_never_reaches_manifest_digest_or_stream() {
    let run = |name: &str| {
        let path = tmp(name);
        let mut c = cfg();
        c.telemetry = Some(path.to_string_lossy().into_owned());
        let mut t = Trainer::new(c, workload(7)).unwrap();
        let report = t.run();
        assert!(t.telemetry_error().is_none(), "{:?}", t.telemetry_error());
        drop(t);
        let stream = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).unwrap();
        (report, stream)
    };
    let (ra, sa) = run("wall_a.jsonl");
    let (rb, sb) = run("wall_b.jsonl");

    // Wall time was measured — the report side carries it...
    assert!(ra.grad_seconds > 0.0, "grad phase took no wall time?");
    // ...but nothing that replays may contain it: manifests, streams
    // and digest inputs are byte-identical across runs whose wall
    // clocks were not.
    assert_eq!(ra.manifest, rb.manifest, "manifest drifted between identical runs");
    assert_eq!(sa, sb, "telemetry stream drifted between identical runs");
    assert_eq!(replay_digest(&ra, None), replay_digest(&rb, None), "digest inputs drifted");
    // And the serialized surfaces never name the wall-time fields.
    for (what, text) in [("manifest", &ra.manifest), ("stream", &sa)] {
        for field in ["grad_seconds", "update_seconds"] {
            assert!(!text.contains(field), "{what} leaked wall-clock field {field}");
        }
    }
}
