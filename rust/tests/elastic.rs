//! Elastic-membership and checkpoint/resume property suites
//! (DESIGN.md §9).
//!
//! The load-bearing claims:
//!
//! * **Resume equivalence** — save → restore → continue is bitwise
//!   identical to an uninterrupted run, across every optimizer ×
//!   {raw fp32, int8+EF codec} × {fault-free, drop=0.1}, through the
//!   checksummed snapshot byte format.
//! * **Mixing invariants under churn** — after every join/leave
//!   resize, the rebuilt Metropolis–Hastings weights have unit row
//!   sums and are exactly symmetric, and the roster stays inside its
//!   bounds.
//!
//! Nightly (`--include-ignored`) additionally runs a larger chained
//! checkpoint round-trip with churn + faults + codec all active.

use decentlam::comm::CommEngine;
use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::elastic::Snapshot;
use decentlam::grad::mlp;
use decentlam::optim;
use decentlam::util::config::{Config, LrSchedule};

fn data(nodes: usize, samples: usize) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: samples,
        eval_samples: 64,
        dirichlet_alpha: 0.5,
        seed: 3,
        ..Default::default()
    })
}

fn workload(data: &ClassificationData, micro_batch: usize) -> decentlam::grad::Workload {
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data.clone(), micro_batch, 3)
}

fn base_cfg(optimizer: &str, nodes: usize, steps: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.total_batch = nodes * 16;
    cfg.micro_batch = 16;
    cfg.lr = 0.02;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg.seed = 3;
    // Short SlowMo period so its all-reduce + buffer reset crosses the
    // checkpoint boundary in the 6-step runs below.
    cfg.slowmo_period = 3;
    cfg
}

fn model_bits(t: &Trainer) -> Vec<u32> {
    t.average_model().iter().map(|v| v.to_bits()).collect()
}

/// Drive `cfg` for `steps` steps uninterrupted; also run it with a
/// checkpoint → byte round-trip → resume at `cut`, and assert every
/// post-cut loss and the final model match bit for bit.
fn assert_resume_equivalent(cfg: &Config, data: &ClassificationData, cut: usize, label: &str) {
    let steps = cfg.steps;
    let mut full = Trainer::new(cfg.clone(), workload(data, cfg.micro_batch)).unwrap();
    let mut ref_losses = Vec::new();
    for k in 0..steps {
        ref_losses.push(full.step(k));
    }
    assert!(ref_losses.iter().all(|l| l.is_finite()), "{label}: non-finite reference");

    let mut first = Trainer::new(cfg.clone(), workload(data, cfg.micro_batch)).unwrap();
    for (k, want) in ref_losses.iter().take(cut).enumerate() {
        assert_eq!(first.step(k), *want, "{label}: prefix diverged at step {k}");
    }
    let bytes = first.checkpoint().to_bytes();
    let snap = Snapshot::from_bytes(&bytes).expect("snapshot bytes must round-trip");
    let mut resumed =
        Trainer::resume(cfg.clone(), workload(data, cfg.micro_batch), &snap).unwrap();
    for (k, want) in ref_losses.iter().enumerate().skip(cut) {
        assert_eq!(resumed.step(k), *want, "{label}: resumed run diverged at step {k}");
    }
    assert_eq!(
        model_bits(&full),
        model_bits(&resumed),
        "{label}: final average model differs after resume"
    );
    match (full.fault_stats(), resumed.fault_stats()) {
        (Some(a), Some(b)) => assert_eq!(a, b, "{label}: fault stats diverged"),
        (None, None) => {}
        _ => panic!("{label}: fault-engine presence diverged across resume"),
    }
}

#[test]
fn resume_equivalence_across_all_optimizers_codecs_and_faults() {
    // The satellite matrix: every optimizer × {fp32, int8+EF} ×
    // {fault-free, drop=0.1}, checkpoint at the midpoint of 6 steps.
    let d = data(4, 64);
    for name in optim::ALL.iter().chain([&"dsgd"]) {
        for codec in ["", "int8,ef=true,seed=5"] {
            for faults in ["", "drop=0.1,seed=9"] {
                let mut cfg = base_cfg(name, 4, 6);
                cfg.apply_kv("codec", codec).unwrap();
                cfg.apply_kv("faults", faults).unwrap();
                let label = format!("{name} codec=[{codec}] faults=[{faults}]");
                assert_resume_equivalent(&cfg, &d, 3, &label);
            }
        }
    }
}

#[test]
fn resume_equivalence_with_stale_replay_cache() {
    // Stragglers exercise the publish cache: the snapshot must carry
    // last round's published payloads or the first resumed round would
    // replay the wrong bytes.
    let d = data(4, 64);
    for codec in ["", "int8,ef=true,seed=5"] {
        let mut cfg = base_cfg("decentlam", 4, 8);
        cfg.apply_kv("codec", codec).unwrap();
        cfg.apply_kv("faults", "straggle=0.4,seed=6").unwrap();
        assert_resume_equivalent(&cfg, &d, 4, &format!("straggle codec=[{codec}]"));
    }
}

#[test]
fn resume_equivalence_under_async_ring_history() {
    // Bounded staleness serves payloads from per-slot ring caches; the
    // snapshot carries the rings, so a resumed run replays the exact
    // same aged payloads. da-dmsgd exercises two exchange slots.
    let d = data(4, 64);
    for name in ["decentlam", "da-dmsgd"] {
        let mut cfg = base_cfg(name, 4, 8);
        cfg.apply_kv("async", "tau=2,spread=6,jitter=0.3,seed=9").unwrap();
        assert_resume_equivalent(&cfg, &d, 4, &format!("{name} async"));
    }
}

#[test]
fn resume_equivalence_under_active_churn() {
    let d = data(6, 64);
    for name in ["decentlam", "dmsgd", "pmsgd"] {
        let mut cfg = base_cfg(name, 4, 10);
        cfg.apply_kv("churn", "join=0.2,leave=0.2,nmin=2,nmax=6,seed=8").unwrap();
        assert_resume_equivalent(&cfg, &d, 5, &format!("{name} churn"));
    }
}

#[test]
fn mh_invariants_hold_after_every_resize() {
    let d = data(8, 48);
    let mut cfg = base_cfg("decentlam", 5, 30);
    cfg.apply_kv("churn", "join=0.3,leave=0.3,nmin=2,nmax=8,seed=4").unwrap();
    let mut t = Trainer::new(cfg, workload(&d, 16)).unwrap();
    let mut sizes = std::collections::BTreeSet::new();
    for k in 0..30 {
        let loss = t.step(k);
        assert!(loss.is_finite(), "step {k}");
        let m = t.active_nodes();
        sizes.insert(m);
        assert!((2..=8).contains(&m), "step {k}: roster size {m} out of bounds");
        assert_eq!(t.comm.n(), m, "step {k}: comm engine out of sync with roster");
        // Row sums: symmetric doubly stochastic at every size.
        assert!(
            t.comm.row_sum_error() < 1e-5,
            "step {k}: row-sum error {} at n={m}",
            t.comm.row_sum_error()
        );
        // Exact symmetry: w_ij present <=> w_ji present with the same
        // bits (the MH rule computes both sides identically).
        for i in 0..m {
            for &(j, w) in t.comm.row(i) {
                let j = j as usize;
                if j == i {
                    continue;
                }
                let back = t.comm.row(j).iter().find(|&&(jj, _)| jj as usize == i);
                match back {
                    Some(&(_, wb)) => assert_eq!(
                        w.to_bits(),
                        wb.to_bits(),
                        "step {k}: w[{i}][{j}] asymmetric at n={m}"
                    ),
                    None => panic!("step {k}: edge ({i},{j}) missing its mirror at n={m}"),
                }
            }
        }
    }
    let stats = t.churn_stats().unwrap();
    assert!(stats.resizes > 0, "join=leave=0.3 never resized");
    assert!(sizes.len() > 1, "roster size never changed: {sizes:?}");
}

#[test]
fn roster_evolution_is_deterministic() {
    let d = data(6, 48);
    let run = || {
        let mut cfg = base_cfg("dmsgd", 4, 20);
        cfg.apply_kv("churn", "join=0.25,leave=0.25,nmin=2,nmax=6,seed=11").unwrap();
        let mut t = Trainer::new(cfg, workload(&d, 16)).unwrap();
        let mut trace = Vec::new();
        for k in 0..20 {
            t.step(k);
            trace.push(t.active_ids());
        }
        trace
    };
    assert_eq!(run(), run(), "roster evolution must replay identically");
}

#[test]
fn join_only_churn_grows_the_fleet_with_finite_training() {
    let d = data(6, 48);
    let mut cfg = base_cfg("decentlam", 2, 30);
    cfg.apply_kv("churn", "join=0.3,leave=0,nmin=2,nmax=6,seed=2").unwrap();
    let mut t = Trainer::new(cfg, workload(&d, 16)).unwrap();
    let report = t.run();
    assert!(report.losses.iter().all(|l| l.is_finite()));
    let stats = t.churn_stats().unwrap();
    assert!(stats.joins > 0, "join=0.3 with 4 parked ids never joined");
    assert_eq!(stats.leaves, 0);
    assert!(t.active_nodes() > 2, "fleet never grew past the initial roster");
}

/// Nightly: a larger chained round-trip — churn + faults + codec all
/// active, checkpoint twice (the second from an already-resumed run),
/// every segment bitwise identical to the uninterrupted reference.
#[test]
#[ignore]
fn nightly_chained_checkpoints_compose_with_churn_faults_and_codec() {
    let d = data(12, 96);
    let mut cfg = base_cfg("decentlam", 8, 60);
    cfg.total_batch = 8 * 16;
    cfg.apply_kv("churn", "join=0.1,leave=0.1,nmin=4,nmax=12,seed=13").unwrap();
    cfg.apply_kv("faults", "drop=0.1,straggle=0.2,seed=7").unwrap();
    cfg.apply_kv("codec", "int8,ef=true,seed=5").unwrap();

    let mut full = Trainer::new(cfg.clone(), workload(&d, 16)).unwrap();
    let mut ref_losses = Vec::new();
    for k in 0..60 {
        ref_losses.push(full.step(k));
    }

    // Segment 1: 0..20, checkpoint.
    let mut a = Trainer::new(cfg.clone(), workload(&d, 16)).unwrap();
    for (k, want) in ref_losses.iter().take(20).enumerate() {
        assert_eq!(a.step(k), *want, "segment 1 diverged at {k}");
    }
    let snap1 = Snapshot::from_bytes(&a.checkpoint().to_bytes()).unwrap();
    // Segment 2: resume, 20..40, checkpoint again FROM THE RESUMED run.
    let mut b = Trainer::resume(cfg.clone(), workload(&d, 16), &snap1).unwrap();
    for (k, want) in ref_losses.iter().enumerate().take(40).skip(20) {
        assert_eq!(b.step(k), *want, "segment 2 diverged at {k}");
    }
    let snap2 = Snapshot::from_bytes(&b.checkpoint().to_bytes()).unwrap();
    // Segment 3: resume the resumed checkpoint, 40..60.
    let mut c = Trainer::resume(cfg, workload(&d, 16), &snap2).unwrap();
    for (k, want) in ref_losses.iter().enumerate().skip(40) {
        assert_eq!(c.step(k), *want, "segment 3 diverged at {k}");
    }
    assert_eq!(model_bits(&full), model_bits(&c), "chained resume final model differs");
    assert_eq!(full.fault_stats().unwrap(), c.fault_stats().unwrap());
    assert_eq!(full.churn_stats().unwrap(), c.churn_stats().unwrap());
}
