//! Persistent worker-pool property suite (DESIGN.md §13).
//!
//! The pool replaces spawn-per-phase threading in the executor; its
//! contract is that this is invisible everywhere except wall-clock:
//!
//! * pool == spawn-per-phase == serial, bitwise, for every optimizer
//!   and for fleet-scale gossip (n = 4096 per PR, n = 65536 nightly);
//! * the worker count is a function of `threads` alone — never of the
//!   fleet size, which elastic churn resizes under the pool's feet;
//! * `rebuild_metropolis` never reallocates after the trainer's
//!   `reserve_for` warmup at nmax;
//! * a panic inside any lane propagates to the caller instead of
//!   deadlocking the epoch barrier, and the pool stays usable after;
//! * chunk boundaries come from ONE per-phase plan, pinned here for
//!   every n ≤ 4096 so the geometry (and thus bitwise results) can
//!   never drift from the pre-pool executor.
//!
//! Every test name contains `parallel`, so the nightly ThreadSanitizer
//! job runs this whole suite.

use std::panic::{catch_unwind, AssertUnwindSafe};

use decentlam::coordinator::{NodeExecutor, Trainer};
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::mlp;
use decentlam::optim::{
    self, partial_average_all_par, NodeState, RoundCtx, Scratch,
};
use decentlam::topology::{metropolis_hastings, Kind, SparseWeights, Topology};
use decentlam::util::config::Config;
use decentlam::util::rng::Pcg64;

/// Drive `rounds` optimizer rounds through `exec` and return the final
/// model bits of every node. Gradients are drawn from per-(step, node)
/// seeded streams, so every executor sees identical inputs.
fn run_rounds(name: &str, exec: &NodeExecutor, rounds: usize) -> Vec<u32> {
    let (n, d) = (24usize, 33usize);
    let wm = metropolis_hastings(&Topology::at_step(Kind::SymExp, n, 1, 0));
    // SlowMo period 3 < rounds, so its all-reduce + reset fires inside
    // the window for the slowmo optimizer.
    let mut o = optim::build(name, 3, 0.7).unwrap();
    let mut states: Vec<NodeState> = (0..n)
        .map(|i| {
            let mut x0 = vec![0.0f32; d];
            Pcg64::seeded(7 + i as u64).normal_fill(&mut x0, 1.0);
            NodeState::new(x0, o.aux_count())
        })
        .collect();
    let mut scratch = Scratch::new(n, d);
    let mut grads = vec![vec![0.0f32; d]; n];
    for step in 0..rounds {
        for (i, g) in grads.iter_mut().enumerate() {
            Pcg64::seeded(1000 + step as u64 * 100 + i as u64).normal_fill(g, 0.5);
        }
        let ctx = RoundCtx {
            exec: exec.clone(),
            ..RoundCtx::new(&wm, 0.05, 0.9, step, false)
        };
        o.round(&mut states, &grads, &ctx, &mut scratch);
    }
    states.iter().flat_map(|s| s.x.iter().map(|v| v.to_bits())).collect()
}

#[test]
fn parallel_pool_matches_spawn_and_serial_across_all_optimizers() {
    for name in optim::ALL.iter().chain([&"dsgd"]) {
        let serial = run_rounds(name, &NodeExecutor::serial(), 4);
        let spawn = run_rounds(name, &NodeExecutor::spawn_per_phase(4), 4);
        let pool = run_rounds(name, &NodeExecutor::new(4), 4);
        assert_eq!(serial, spawn, "{name}: spawn-per-phase diverged from serial");
        assert_eq!(serial, pool, "{name}: persistent pool diverged from serial");
    }
}

#[test]
fn parallel_phase_plan_chunk_boundaries_pinned_for_every_n() {
    // The pre-pool executor derived `chunk = ceil(n / min(threads, n))`
    // and cut blocks at [b·chunk, min((b+1)·chunk, n)). The plan (now
    // computed once per phase) must reproduce exactly that geometry for
    // every n — different boundaries would reorder nothing arithmetic-
    // wise per element, but this pin makes any drift loud anyway.
    for threads in [1usize, 2, 3, 4, 7, 8, 64] {
        let exec = NodeExecutor::new(threads);
        for n in 1usize..=4096 {
            let plan = exec.phase_plan(n);
            let workers = threads.min(n).max(1);
            let chunk = (n + workers - 1) / workers;
            assert_eq!(plan.n, n);
            assert_eq!(plan.chunk, chunk, "threads={threads} n={n}");
            assert_eq!(plan.blocks, (n + chunk - 1) / chunk, "threads={threads} n={n}");
            assert!(plan.blocks <= threads, "threads={threads} n={n}: too many blocks");
            // Blocks partition 0..n: contiguous, in order, non-empty.
            let mut covered = 0usize;
            for b in 0..plan.blocks {
                let start = b * plan.chunk;
                let end = (start + plan.chunk).min(n);
                assert_eq!(start, covered, "threads={threads} n={n} block {b}: gap");
                assert!(end > start, "threads={threads} n={n} block {b}: empty");
                covered = end;
            }
            assert_eq!(covered, n, "threads={threads} n={n}: blocks do not cover 0..n");
        }
    }
}

#[test]
fn parallel_pool_worker_count_independent_of_fleet_size() {
    let exec = NodeExecutor::new(4);
    assert_eq!(exec.pool_workers(), None, "pool must start lazily");
    let clone = exec.clone();
    // Phases over wildly different n: the pool is created once with
    // threads-1 workers and never resized — elastic churn changes n
    // every few steps and must not touch thread count.
    for n in [64usize, 1000, 3, 4096, 1] {
        let mut v = vec![1.0f32; n];
        clone.for_each_mut(&mut v, |i, x| *x += i as f32);
        assert_eq!(exec.pool_workers(), Some(3), "after phase over n={n}");
        assert_eq!(clone.pool_workers(), Some(3), "clone must share the pool");
    }
}

#[test]
fn parallel_panic_in_worker_propagates_without_deadlock() {
    let exec = NodeExecutor::new(4);
    // n=100, threads=4 → chunk 25: i==57 lands on a pool worker's lane,
    // i==7 on the caller's own lane 0. Both must surface as a panic on
    // the calling thread — and the pool must stay usable afterwards.
    for bad in [57usize, 7] {
        let result = catch_unwind(AssertUnwindSafe(|| {
            let mut v = vec![0u8; 100];
            exec.for_each_mut(&mut v, |i, _x| {
                assert!(i != bad, "injected failure at {i}");
            });
        }));
        assert!(result.is_err(), "panic at i={bad} must propagate to the caller");
        let mut v = vec![0u32; 100];
        exec.for_each_mut(&mut v, |i, x| *x = i as u32 + 1);
        assert!(
            v.iter().enumerate().all(|(i, &x)| x == i as u32 + 1),
            "pool must survive a panicking phase (bad={bad})"
        );
    }
}

fn churn_cfg(threads: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = "decentlam".into();
    cfg.nodes = 4;
    cfg.steps = 12;
    cfg.total_batch = 4 * 16;
    cfg.micro_batch = 16;
    cfg.lr = 0.02;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.topology = "ring".into();
    cfg.seed = 3;
    cfg.threads = threads;
    cfg.apply_kv("churn", "join=0.2,leave=0.2,nmin=2,nmax=6,seed=8").unwrap();
    cfg
}

fn churn_workload(cfg: &Config) -> decentlam::grad::Workload {
    // One shard per stable id (nmax = 6).
    let data = ClassificationData::generate(&SynthSpec {
        nodes: 6,
        samples_per_node: 64,
        eval_samples: 64,
        dirichlet_alpha: 0.5,
        seed: 3,
        ..Default::default()
    });
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, cfg.micro_batch, 3)
}

#[test]
fn parallel_pool_survives_churn_and_rebuilds_without_reallocating() {
    // Pooled and serial trainers must agree bitwise through elastic
    // resizes, and the CSR arenas — warmed at nmax in Trainer::new —
    // must never grow while churn oscillates the fleet.
    let cfg_par = churn_cfg(4);
    let cfg_ser = churn_cfg(1);
    let mut par = Trainer::new(cfg_par.clone(), churn_workload(&cfg_par)).unwrap();
    let mut ser = Trainer::new(cfg_ser.clone(), churn_workload(&cfg_ser)).unwrap();
    let warm = par.comm.arena_capacity();
    for k in 0..cfg_par.steps {
        let (lp, ls) = (par.step(k), ser.step(k));
        assert_eq!(lp.to_bits(), ls.to_bits(), "step {k}: pooled loss diverged");
        assert_eq!(
            par.comm.arena_capacity(),
            warm,
            "step {k}: rebuild_metropolis reallocated after warmup"
        );
    }
    let a: Vec<u32> = par.average_model().iter().map(|v| v.to_bits()).collect();
    let b: Vec<u32> = ser.average_model().iter().map(|v| v.to_bits()).collect();
    assert_eq!(a, b, "final model diverged under churn");
}

/// `steps` gossip+update iterations at fleet scale: one partial
/// average through `exec`, then a deterministic per-node update, also
/// through `exec`. Returns the final bits of every node.
fn fleet_gossip(kind: Kind, n: usize, d: usize, steps: usize, exec: &NodeExecutor) -> Vec<u32> {
    let sw = SparseWeights::metropolis_hastings(&Topology::build(kind, n));
    let mut x: Vec<Vec<f32>> = (0..n)
        .map(|i| (0..d).map(|k| ((i * 13 + k * 5) % 31) as f32 * 0.0625 - 1.0).collect())
        .collect();
    let mut mixed = vec![vec![0.0f32; d]; n];
    for step in 0..steps {
        partial_average_all_par(&sw, &x, &mut mixed, exec);
        let decay = 1.0 - 1.0 / (step + 2) as f32;
        exec.for_each_pair_mut(&mut x, &mut mixed, |i, xi, mi| {
            for (a, &m) in xi.iter_mut().zip(mi.iter()) {
                *a = m * decay + (i % 7) as f32 * 1e-3;
            }
        });
    }
    x.iter().flat_map(|r| r.iter().map(|v| v.to_bits())).collect()
}

#[test]
fn fleet_gossip_parallel_pool_matches_spawn_bitwise_ring_n4096() {
    let (n, d, steps) = (4096usize, 16usize, 20usize);
    let serial = fleet_gossip(Kind::Ring, n, d, steps, &NodeExecutor::serial());
    let spawn = fleet_gossip(Kind::Ring, n, d, steps, &NodeExecutor::spawn_per_phase(4));
    let pool = fleet_gossip(Kind::Ring, n, d, steps, &NodeExecutor::new(4));
    assert_eq!(serial, spawn, "spawn-per-phase diverged at n={n}");
    assert_eq!(serial, pool, "persistent pool diverged at n={n}");
}

#[test]
#[ignore = "fleet-scale sweep (n=65536); nightly --include-ignored tier"]
fn fleet_gossip_parallel_pool_matches_spawn_bitwise_n65536() {
    let (n, d, steps) = (65536usize, 8usize, 3usize);
    for kind in [Kind::Ring, Kind::SymExp] {
        let serial = fleet_gossip(kind, n, d, steps, &NodeExecutor::serial());
        let spawn = fleet_gossip(kind, n, d, steps, &NodeExecutor::spawn_per_phase(8));
        let pool = fleet_gossip(kind, n, d, steps, &NodeExecutor::new(8));
        assert_eq!(serial, spawn, "{kind:?}: spawn-per-phase diverged at n={n}");
        assert_eq!(serial, pool, "{kind:?}: persistent pool diverged at n={n}");
    }
}
