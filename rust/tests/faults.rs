//! Fault-injection suite (DESIGN.md §6): property tests over the
//! masked/renormalized mixing weights and a deterministic scenario
//! harness running every fault class end to end through the trainer.
//!
//! The four tentpole invariants:
//! (a) masked matrices stay symmetric doubly stochastic after
//!     renormalization,
//! (b) a `FaultPlan` replays bit-identical schedules per seed,
//! (c) zero-rate plans are bitwise identical to the fault-free engine,
//! (d) parallel execution stays bitwise equal to serial under faults.
//!
//! Scenario tests marked `#[ignore]` are the slow nightly tier
//! (`cargo test -q -- --include-ignored`).

use decentlam::comm::CommEngine;
use decentlam::coordinator::{NodeExecutor, Trainer};
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::{mlp, Workload};
use decentlam::optim::{partial_average_all, partial_average_all_par};
use decentlam::prop::{check, gens};
use decentlam::sim::{FaultPlan, FaultSpec, FaultyEngine};
use decentlam::topology::{Kind, SparseWeights, Topology};
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::rng::Pcg64;

const KINDS: [Kind; 5] = [Kind::Ring, Kind::Mesh, Kind::Star, Kind::SymExp, Kind::Full];

fn random_spec(rng: &mut Pcg64) -> FaultSpec {
    FaultSpec {
        drop: rng.f64() * 0.6,
        link: rng.f64() * 0.6,
        straggle: rng.f64() * 0.6,
        stale: rng.f64() * 0.6,
        seed: rng.next_u64(),
        ..Default::default()
    }
}

fn realized(spec: FaultSpec, topo: &Topology, step: usize) -> FaultyEngine {
    let nominal = SparseWeights::metropolis_hastings(topo);
    let mut f = FaultyEngine::new(FaultPlan::new(spec));
    f.begin_step(step, &nominal);
    f
}

#[test]
fn prop_masked_matrices_stay_doubly_stochastic() {
    // (a) Whatever the rates mask, the renormalized weights must stay
    // symmetric, non-negative, row-stochastic, with positive diagonal.
    check(
        "masked + renormalized weights are symmetric doubly stochastic",
        60,
        |rng| {
            let kind = KINDS[rng.below(KINDS.len())];
            let n = gens::nodes(rng);
            (kind, n, random_spec(rng), rng.below(50))
        },
        |&(kind, n, spec, step)| {
            let topo = Topology::build(kind, n);
            let f = realized(spec, &topo, step);
            if f.row_sum_error() > 1e-6 {
                return Err(format!("row sums off by {}", f.row_sum_error()));
            }
            for i in 0..n {
                if f.self_weight(i) <= 0.0 {
                    return Err(format!("w_{i}{i} <= 0"));
                }
                for &(j, w) in f.row(i) {
                    if w < 0.0 {
                        return Err(format!("negative w[{i}][{j}]"));
                    }
                    // Symmetry: the mirrored entry must exist and match.
                    let ju = j as usize;
                    if ju != i {
                        let Some(&(_, wm)) =
                            f.row(ju).iter().find(|&&(jj, _)| jj as usize == i)
                        else {
                            return Err(format!("edge ({i},{ju}) not mirrored"));
                        };
                        if (w - wm).abs() > 1e-7 {
                            return Err(format!("asymmetric: w[{i}][{ju}]={w} vs {wm}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fault_schedule_replays_per_seed() {
    // (b) Same spec => identical realized rows at every step; the
    // schedule is a pure function of (seed, step, entity).
    check(
        "fault schedules replay bit-identically per seed",
        40,
        |rng| {
            let kind = KINDS[rng.below(KINDS.len())];
            let n = gens::nodes(rng);
            (kind, n, random_spec(rng), rng.below(100))
        },
        |&(kind, n, spec, step)| {
            let topo = Topology::build(kind, n);
            let a = realized(spec, &topo, step);
            let b = realized(spec, &topo, step);
            for i in 0..n {
                if a.row(i) != b.row(i) {
                    return Err(format!("row {i} differs across replays"));
                }
            }
            if a.stats() != b.stats() {
                return Err("stats differ across replays".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_zero_rates_bitwise_match_fault_free_engine() {
    // (c) A zero-rate plan must be indistinguishable — rows AND mixed
    // output, bit for bit — from the plain sparse engine.
    check(
        "zero-rate fault engine is bitwise the fault-free engine",
        40,
        |rng| {
            let kind = KINDS[rng.below(KINDS.len())];
            let n = gens::nodes(rng);
            let d = 1 + rng.below(32);
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (kind, rng.next_u64(), rng.below(20), src)
        },
        |(kind, seed, step, src)| {
            let n = src.len();
            let d = src[0].len();
            let topo = Topology::build(*kind, n);
            let nominal = SparseWeights::metropolis_hastings(&topo);
            let spec = FaultSpec { seed: *seed, ..Default::default() };
            let mut f = FaultyEngine::new(FaultPlan::new(spec));
            f.begin_step(*step, &nominal);
            for i in 0..n {
                if f.row(i) != nominal.row(i) {
                    return Err(format!("row {i} differs from nominal"));
                }
            }
            let mut out_f = vec![vec![0.0f32; d]; n];
            let mut out_n = vec![vec![0.0f32; d]; n];
            partial_average_all(&f, src, &mut out_f);
            partial_average_all(&nominal, src, &mut out_n);
            if out_f != out_n {
                return Err("mixed output differs from nominal".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_mixing_bitwise_matches_serial_under_faults() {
    // (d) Chunked threads never reorder per-row arithmetic, stale
    // entries included.
    check(
        "parallel faulty mixing is bitwise identical to serial",
        30,
        |rng| {
            let kind = KINDS[rng.below(KINDS.len())];
            let n = gens::nodes(rng);
            let d = 1 + rng.below(48);
            let threads = 2 + rng.below(7);
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            let prev: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (kind, random_spec(rng), threads, src, prev)
        },
        |(kind, spec, threads, src, prev)| {
            let n = src.len();
            let d = src[0].len();
            let topo = Topology::build(*kind, n);
            let nominal = SparseWeights::metropolis_hastings(&topo);
            let mut f = FaultyEngine::new(FaultPlan::new(*spec));
            // Warm the stale cache so straggle/stale entries resolve
            // against `prev` — the hardest path to keep deterministic.
            f.begin_step(0, &nominal);
            f.record_publish(prev);
            f.begin_step(1, &nominal);
            let mut serial = vec![vec![0.0f32; d]; n];
            let mut parallel = vec![vec![0.0f32; d]; n];
            partial_average_all(&f, src, &mut serial);
            partial_average_all_par(&f, src, &mut parallel, &NodeExecutor::new(*threads));
            if serial != parallel {
                return Err("parallel faulty mixing differs from serial".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Deterministic scenario harness: named fault regimes run end to end
// through the trainer; each must stay finite and replay bit-identically.
// ---------------------------------------------------------------------

struct Scenario {
    name: &'static str,
    optimizer: &'static str,
    topology: &'static str,
    faults: &'static str,
    nodes: usize,
    steps: usize,
}

const SCENARIOS: [Scenario; 5] = [
    Scenario {
        name: "ring-dropout",
        optimizer: "decentlam",
        topology: "ring",
        faults: "drop=0.2,seed=11",
        nodes: 8,
        steps: 30,
    },
    Scenario {
        name: "exp-link-failures",
        optimizer: "dmsgd",
        topology: "sym-exp",
        faults: "link=0.3,seed=12",
        nodes: 8,
        steps: 30,
    },
    Scenario {
        name: "ring-stragglers",
        optimizer: "decentlam",
        topology: "ring",
        faults: "straggle=0.25,seed=13",
        nodes: 6,
        steps: 30,
    },
    Scenario {
        name: "stale-links-time-varying",
        optimizer: "dsgd",
        topology: "one-peer-exp",
        faults: "stale=0.2,link=0.1,seed=14",
        nodes: 8,
        steps: 30,
    },
    Scenario {
        name: "star-hub-under-everything",
        optimizer: "qg-dmsgd",
        topology: "star",
        faults: "drop=0.1,link=0.1,straggle=0.1,stale=0.1,seed=15",
        nodes: 6,
        steps: 30,
    },
];

fn scenario_workload(nodes: usize, seed: u64) -> Workload {
    let data = ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 128,
        eval_samples: 128,
        dirichlet_alpha: 0.5,
        seed,
        ..Default::default()
    });
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 16, seed)
}

fn scenario_cfg(s: &Scenario) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = s.optimizer.into();
    cfg.topology = s.topology.into();
    cfg.nodes = s.nodes;
    cfg.steps = s.steps;
    cfg.total_batch = 16 * s.nodes;
    cfg.micro_batch = 16;
    cfg.lr = 0.02;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.seed = 5;
    cfg.apply_kv("faults", s.faults).unwrap();
    cfg
}

fn run_scenario(s: &Scenario) -> (Vec<f64>, f64) {
    let mut t = Trainer::new(scenario_cfg(s), scenario_workload(s.nodes, 5)).unwrap();
    let r = t.run();
    (r.losses, r.final_consensus)
}

#[test]
fn scenarios_stay_finite_and_replay_identically() {
    for s in &SCENARIOS {
        let (losses, consensus) = run_scenario(s);
        assert!(
            losses.iter().all(|l| l.is_finite()),
            "{}: non-finite loss",
            s.name
        );
        assert!(consensus.is_finite(), "{}: non-finite consensus", s.name);
        let (replay, replay_consensus) = run_scenario(s);
        assert_eq!(losses, replay, "{}: replay diverged", s.name);
        assert_eq!(consensus, replay_consensus, "{}: consensus replay diverged", s.name);
    }
}

#[test]
fn scenario_faults_actually_fire() {
    for s in &SCENARIOS {
        let mut t = Trainer::new(scenario_cfg(s), scenario_workload(s.nodes, 5)).unwrap();
        for k in 0..s.steps {
            t.step(k);
        }
        let stats = t.fault_stats().expect(s.name);
        assert_eq!(stats.steps, s.steps, "{}", s.name);
        assert!(
            stats.masked_edges + stats.stale_messages > 0,
            "{}: no fault ever realized",
            s.name
        );
    }
}

#[test]
fn trainer_threads_agree_under_faults() {
    // (d) at trainer level: a faulty run fans the same arithmetic over
    // however many threads.
    let run = |threads: usize| {
        let mut cfg = scenario_cfg(&SCENARIOS[0]);
        cfg.threads = threads;
        let mut t = Trainer::new(cfg, scenario_workload(SCENARIOS[0].nodes, 5)).unwrap();
        t.run().losses
    };
    assert_eq!(run(1), run(4), "threading changed faulty-run results");
}

/// Slow nightly tier: every optimizer under dropout + stragglers for
/// 120 steps; losses must stay finite and end below where they start.
#[test]
#[ignore = "slow scenario sweep — nightly tier (--include-ignored)"]
fn slow_all_optimizers_survive_fault_mix() {
    for name in decentlam::optim::ALL.iter().chain([&"dsgd"]) {
        let s = Scenario {
            name: "nightly-mix",
            optimizer: "", // overridden below
            topology: "ring",
            faults: "drop=0.1,link=0.05,straggle=0.1,seed=21",
            nodes: 8,
            steps: 120,
        };
        let mut cfg = scenario_cfg(&s);
        cfg.optimizer = (*name).into();
        let mut t = Trainer::new(cfg, scenario_workload(s.nodes, 5)).unwrap();
        let r = t.run();
        assert!(
            r.losses.iter().all(|l| l.is_finite()),
            "{name}: diverged under fault mix"
        );
        let first = r.losses[..10].iter().sum::<f64>() / 10.0;
        let last = r.losses[r.losses.len() - 10..].iter().sum::<f64>() / 10.0;
        assert!(last < first, "{name}: no progress under fault mix ({first} -> {last})");
    }
}

/// Slow nightly tier: drop-rate sweep keeps the DecentLaM bias gap.
#[test]
#[ignore = "slow scenario sweep — nightly tier (--include-ignored)"]
fn slow_fig_faults_default_sweep_is_deterministic() {
    use decentlam::experiments::fig_faults;
    let opts = fig_faults::Opts { nodes: 16, steps: 120, ..Default::default() };
    let (rows, table) = fig_faults::run(&opts).unwrap();
    let (_, again) = fig_faults::run(&opts).unwrap();
    assert_eq!(table.render(), again.render());
    assert!(rows.iter().all(|r| r.consensus.is_finite()));
}
