//! Golden-vector cross-layer tests: replay the oracle evaluations that
//! `python/compile/aot.py` serialized into `artifacts/golden.json`
//! against the native Rust implementations — one source of truth across
//! Pallas kernel (L1), jnp oracle (L2) and Rust fast path (L3).
//!
//! Requires `make artifacts`. Without the artifact every test in this
//! file returns early through [`load_golden`], which prints ONE
//! explicit `SKIPPED:` line — CI greps for it and fails the build if
//! the golden tests skipped on a runner where the artifact exists
//! (silent skips previously looked identical to passes).

use std::path::Path;
use std::sync::Once;

use decentlam::optim::decentlam::fused_apply;
use decentlam::util::json::Value;

/// The single skip gate for this suite: `None` means "no artifact — the
/// caller must return without asserting anything", reported exactly
/// once, on stdout, with a greppable marker.
fn load_golden() -> Option<Value> {
    static REPORT: Once = Once::new();
    let path = Path::new("artifacts/golden.json");
    if !path.exists() {
        REPORT.call_once(|| {
            println!(
                "SKIPPED: golden tests (artifacts/golden.json missing — run `make artifacts`)"
            );
        });
        return None;
    }
    Some(Value::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

#[test]
fn native_fused_apply_matches_pallas_oracle() {
    let Some(g) = load_golden() else { return };
    let u = g.get("decentlam_update").unwrap();
    let k = u.get("k").unwrap().as_usize().unwrap();
    let d = u.get("d").unwrap().as_usize().unwrap();
    let z = u.get("z").unwrap().as_f32_vec().unwrap();
    let w = u.get("w").unwrap().as_f32_vec().unwrap();
    let mut x = u.get("x").unwrap().as_f32_vec().unwrap();
    let mut m = u.get("m").unwrap().as_f32_vec().unwrap();
    let gamma = u.get("gamma").unwrap().as_f64().unwrap() as f32;
    let beta = u.get("beta").unwrap().as_f64().unwrap() as f32;
    let x_want = u.get("x_new").unwrap().as_f32_vec().unwrap();
    let m_want = u.get("m_new").unwrap().as_f32_vec().unwrap();

    // mix = w^T z (the partial-averaging step the kernel fuses).
    let mut mix = vec![0.0f32; d];
    for kk in 0..k {
        for j in 0..d {
            mix[j] += w[kk] * z[kk * d + j];
        }
    }
    fused_apply(&mut x, &mut m, &mix, gamma, beta);
    for j in 0..d {
        assert!(
            (x[j] - x_want[j]).abs() < 1e-4,
            "x[{j}]: rust {} vs oracle {}",
            x[j],
            x_want[j]
        );
        assert!(
            (m[j] - m_want[j]).abs() < 1e-3,
            "m[{j}]: rust {} vs oracle {}",
            m[j],
            m_want[j]
        );
    }
}

#[test]
fn native_partial_average_matches_oracle() {
    let Some(g) = load_golden() else { return };
    let u = g.get("decentlam_update").unwrap();
    let k = u.get("k").unwrap().as_usize().unwrap();
    let d = u.get("d").unwrap().as_usize().unwrap();
    let z = u.get("z").unwrap().as_f32_vec().unwrap();
    let w = u.get("w").unwrap().as_f32_vec().unwrap();
    let want = g
        .get("partial_average")
        .unwrap()
        .get("mix")
        .unwrap()
        .as_f32_vec()
        .unwrap();
    let mut mix = vec![0.0f32; d];
    for kk in 0..k {
        for j in 0..d {
            mix[j] += w[kk] * z[kk * d + j];
        }
    }
    for j in 0..d {
        assert!((mix[j] - want[j]).abs() < 1e-5, "mix[{j}]");
    }
}

#[test]
fn golden_weights_are_stochastic() {
    let Some(g) = load_golden() else { return };
    let w = g
        .get("decentlam_update")
        .unwrap()
        .get("w")
        .unwrap()
        .as_f32_vec()
        .unwrap();
    let s: f32 = w.iter().sum();
    assert!((s - 1.0).abs() < 1e-5);
}
