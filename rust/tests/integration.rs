//! Cross-module integration tests: full training runs through the
//! coordinator on the native engines, the paper's qualitative claims on
//! shrunk workloads, and failure-injection around config/workload
//! mismatches.

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::data::LinRegProblem;
use decentlam::experiments as exp;
use decentlam::grad::{linreg, mlp};
use decentlam::util::config::{Config, LrSchedule};

fn mlp_data(nodes: usize, alpha: f64, seed: u64) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 512,
        eval_samples: 512,
        dirichlet_alpha: alpha,
        seed,
        ..Default::default()
    })
}

fn base_cfg(optimizer: &str, nodes: usize, steps: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.total_batch = 256;
    cfg.micro_batch = 32;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg
}

#[test]
fn large_batch_bias_gap_dmsgd_vs_decentlam() {
    // The paper's central claim on a shrunk workload: at large batch
    // (low gradient noise) + heterogeneous data + momentum, DmSGD's
    // momentum-amplified inconsistency bias shows up as (a) a much
    // larger consensus spread, (b) a worse GLOBAL objective at the
    // average model, and (c) lower validation accuracy. (Per-node
    // *local* loss is the wrong observable: the bias drifts each model
    // toward its local shard's optimum, lowering local loss.)
    let run = |optimizer: &str| -> (f64, f64, f64) {
        let mut cfg = base_cfg(optimizer, 8, 250);
        cfg.total_batch = 2048; // large batch via accumulation
        cfg.momentum = 0.9;
        cfg.lr = 0.08;
        let data = mlp_data(8, 0.1, 3); // strongly heterogeneous
        let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 3);
        let mut t = Trainer::new(cfg, wl).unwrap();
        let r = t.run();
        let xbar = t.average_model();
        let mut g = vec![0.0f32; t.workload.dim];
        let global_loss: f64 = t
            .workload
            .nodes
            .iter_mut()
            .map(|n| n.grad_accum(&xbar, 4, &mut g))
            .sum::<f64>()
            / 8.0;
        (global_loss, r.final_consensus, r.final_accuracy)
    };
    let (dm_loss, dm_cons, dm_acc) = run("dmsgd");
    let (dl_loss, dl_cons, dl_acc) = run("decentlam");
    assert!(
        dl_cons < 0.5 * dm_cons,
        "DecentLaM consensus {dl_cons:.3e} should be well below DmSGD {dm_cons:.3e}"
    );
    assert!(
        dl_loss < dm_loss + 1e-9,
        "global loss at x̄: decentlam {dl_loss} vs dmsgd {dm_loss}"
    );
    assert!(
        dl_acc + 0.02 >= dm_acc,
        "val acc: decentlam {dl_acc} vs dmsgd {dm_acc}"
    );
}

#[test]
fn decentralized_methods_reach_consensus_neighborhood() {
    for optimizer in ["dsgd", "dmsgd", "decentlam", "qg-dmsgd"] {
        let mut cfg = base_cfg(optimizer, 8, 150);
        cfg.lr = 0.03;
        let data = mlp_data(8, 1.0, 1);
        let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
        let mut t = Trainer::new(cfg, wl).unwrap();
        let r = t.run();
        // Consensus distance per parameter should be small relative to
        // the parameter scale after the LR has settled.
        let per_param = r.final_consensus / 4810.0;
        assert!(per_param < 1e-2, "{optimizer}: consensus/param {per_param}");
    }
}

#[test]
fn pmsgd_keeps_nodes_bitwise_identical_through_training() {
    let cfg = base_cfg("pmsgd", 4, 50);
    let data = mlp_data(4, 0.5, 2);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 2);
    let mut t = Trainer::new(cfg, wl).unwrap();
    for k in 0..50 {
        t.step(k);
    }
    for st in &t.states[1..] {
        assert_eq!(st.x, t.states[0].x);
    }
}

#[test]
fn lars_survives_large_batch_with_big_lr() {
    let mut cfg = base_cfg("pmsgd-lars", 4, 120);
    cfg.total_batch = 2048;
    cfg.lr = 8.0; // LARS trust ratio tames this; plain SGD would diverge
    cfg.schedule = LrSchedule::WarmupStep { warmup_steps: 10, milestones: vec![80] };
    let data = mlp_data(4, 1.0, 5);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 5);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.losses.iter().all(|l| l.is_finite()), "LARS run diverged");
    assert!(r.final_accuracy > 0.3, "acc {}", r.final_accuracy);
}

#[test]
fn d2_removes_bias_on_heterogeneous_linreg() {
    // D² and DecentLaM should both beat DmSGD's limiting error.
    let problem = LinRegProblem::generate(8, 30, 12, 4);
    let bias_of = |optimizer: &str| -> f64 {
        let mut cfg = base_cfg(optimizer, 8, 6000);
        cfg.lr = 0.002;
        cfg.momentum = 0.9;
        cfg.threads = 1;
        let mut t = Trainer::new(cfg, linreg::workload(problem.clone())).unwrap();
        for k in 0..6000 {
            t.step(k);
        }
        let xs: Vec<Vec<f32>> = t.states.iter().map(|s| s.x.clone()).collect();
        problem.relative_error(&xs)
    };
    let dmsgd = bias_of("dmsgd");
    let d2 = bias_of("d2-dmsgd");
    let dlam = bias_of("decentlam");
    assert!(d2 < dmsgd, "d2 {d2} vs dmsgd {dmsgd}");
    assert!(dlam < dmsgd, "decentlam {dlam} vs dmsgd {dmsgd}");
}

#[test]
fn schedule_decays_learning_rate_in_training() {
    let mut cfg = base_cfg("decentlam", 4, 90);
    cfg.schedule = LrSchedule::WarmupStep { warmup_steps: 5, milestones: vec![30, 60] };
    assert!(cfg.lr_at(0) < cfg.lr_at(4));
    assert!(cfg.lr_at(40) < cfg.lr_at(20));
    assert!(cfg.lr_at(70) < cfg.lr_at(40));
    let data = mlp_data(4, 1.0, 6);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 6);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn experiment_harness_fig6_matches_paper_band() {
    let (rows, table) = exp::fig6::run(&exp::fig6::Opts::default()).unwrap();
    assert!(!rows.is_empty());
    let rendered = table.render();
    assert!(rendered.contains("decentlam"));
    // Headline claim: 1.2-1.9x at the paper's settings (10 Gbps, 2K).
    let r = rows
        .iter()
        .find(|r| r.method == "decentlam" && r.bandwidth_gbps == 10.0 && r.batch == 2048)
        .unwrap();
    assert!(
        (1.1..2.2).contains(&r.speedup_vs_pmsgd),
        "speedup {}",
        r.speedup_vs_pmsgd
    );
}

#[test]
fn failure_injection_bad_configs() {
    // Unknown optimizer.
    let mut cfg = base_cfg("adamw", 4, 5);
    let data = mlp_data(4, 1.0, 1);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
    assert!(Trainer::new(cfg.clone(), wl).is_err());
    // Unknown topology.
    cfg.optimizer = "dmsgd".into();
    cfg.topology = "hypercube-9d".into();
    let data = mlp_data(4, 1.0, 1);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
    assert!(Trainer::new(cfg, wl).is_err());
}

#[test]
fn single_node_degenerates_to_sgd() {
    // n=1 ring: W = [1]; decentlam == plain momentum SGD; must train.
    let mut cfg = base_cfg("decentlam", 1, 100);
    cfg.total_batch = 64;
    let data = mlp_data(1, 100.0, 7);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 7);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.final_accuracy > 0.5, "acc {}", r.final_accuracy);
    assert!(r.final_consensus < 1e-12);
}
