//! Cross-module integration tests: full training runs through the
//! coordinator on the native engines, the paper's qualitative claims on
//! shrunk workloads, and failure-injection around config/workload
//! mismatches.

use decentlam::comm::{wire_bytes_per_iter, CommStats, PayloadBytes};
use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::data::LinRegProblem;
use decentlam::experiments as exp;
use decentlam::grad::{linreg, mlp};
use decentlam::optim::{self, CommPattern, NodeState, RoundCtx, Scratch};
use decentlam::topology::{metropolis_hastings, Kind, Topology};
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::math;

fn mlp_data(nodes: usize, alpha: f64, seed: u64) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 512,
        eval_samples: 512,
        dirichlet_alpha: alpha,
        seed,
        ..Default::default()
    })
}

fn base_cfg(optimizer: &str, nodes: usize, steps: usize) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = nodes;
    cfg.steps = steps;
    cfg.total_batch = 256;
    cfg.micro_batch = 32;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg
}

#[test]
fn large_batch_bias_gap_dmsgd_vs_decentlam() {
    // The paper's central claim on a shrunk workload: at large batch
    // (low gradient noise) + heterogeneous data + momentum, DmSGD's
    // momentum-amplified inconsistency bias shows up as (a) a much
    // larger consensus spread, (b) a worse GLOBAL objective at the
    // average model, and (c) lower validation accuracy. (Per-node
    // *local* loss is the wrong observable: the bias drifts each model
    // toward its local shard's optimum, lowering local loss.)
    let run = |optimizer: &str| -> (f64, f64, f64) {
        let mut cfg = base_cfg(optimizer, 8, 250);
        cfg.total_batch = 2048; // large batch via accumulation
        cfg.momentum = 0.9;
        cfg.lr = 0.08;
        let data = mlp_data(8, 0.1, 3); // strongly heterogeneous
        let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 3);
        let mut t = Trainer::new(cfg, wl).unwrap();
        let r = t.run();
        let xbar = t.average_model();
        let mut g = vec![0.0f32; t.workload.dim];
        let global_loss: f64 = t
            .workload
            .nodes
            .iter_mut()
            .map(|n| n.grad_accum(&xbar, 4, &mut g))
            .sum::<f64>()
            / 8.0;
        (global_loss, r.final_consensus, r.final_accuracy)
    };
    let (dm_loss, dm_cons, dm_acc) = run("dmsgd");
    let (dl_loss, dl_cons, dl_acc) = run("decentlam");
    assert!(
        dl_cons < 0.5 * dm_cons,
        "DecentLaM consensus {dl_cons:.3e} should be well below DmSGD {dm_cons:.3e}"
    );
    assert!(
        dl_loss < dm_loss + 1e-9,
        "global loss at x̄: decentlam {dl_loss} vs dmsgd {dm_loss}"
    );
    assert!(
        dl_acc + 0.02 >= dm_acc,
        "val acc: decentlam {dl_acc} vs dmsgd {dm_acc}"
    );
}

#[test]
fn decentralized_methods_reach_consensus_neighborhood() {
    for optimizer in ["dsgd", "dmsgd", "decentlam", "qg-dmsgd"] {
        let mut cfg = base_cfg(optimizer, 8, 150);
        cfg.lr = 0.03;
        let data = mlp_data(8, 1.0, 1);
        let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
        let mut t = Trainer::new(cfg, wl).unwrap();
        let r = t.run();
        // Consensus distance per parameter should be small relative to
        // the parameter scale after the LR has settled.
        let per_param = r.final_consensus / 4810.0;
        assert!(per_param < 1e-2, "{optimizer}: consensus/param {per_param}");
    }
}

#[test]
fn pmsgd_keeps_nodes_bitwise_identical_through_training() {
    let cfg = base_cfg("pmsgd", 4, 50);
    let data = mlp_data(4, 0.5, 2);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 2);
    let mut t = Trainer::new(cfg, wl).unwrap();
    for k in 0..50 {
        t.step(k);
    }
    for st in &t.states[1..] {
        assert_eq!(st.x, t.states[0].x);
    }
}

#[test]
fn lars_survives_large_batch_with_big_lr() {
    let mut cfg = base_cfg("pmsgd-lars", 4, 120);
    cfg.total_batch = 2048;
    cfg.lr = 8.0; // LARS trust ratio tames this; plain SGD would diverge
    cfg.schedule = LrSchedule::WarmupStep { warmup_steps: 10, milestones: vec![80] };
    let data = mlp_data(4, 1.0, 5);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 5);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.losses.iter().all(|l| l.is_finite()), "LARS run diverged");
    assert!(r.final_accuracy > 0.3, "acc {}", r.final_accuracy);
}

#[test]
fn d2_removes_bias_on_heterogeneous_linreg() {
    // D² and DecentLaM should both beat DmSGD's limiting error.
    let problem = LinRegProblem::generate(8, 30, 12, 4);
    let bias_of = |optimizer: &str| -> f64 {
        let mut cfg = base_cfg(optimizer, 8, 6000);
        cfg.lr = 0.002;
        cfg.momentum = 0.9;
        cfg.threads = 1;
        let mut t = Trainer::new(cfg, linreg::workload(problem.clone())).unwrap();
        for k in 0..6000 {
            t.step(k);
        }
        let xs: Vec<Vec<f32>> = t.states.iter().map(|s| s.x.clone()).collect();
        problem.relative_error(&xs)
    };
    let dmsgd = bias_of("dmsgd");
    let d2 = bias_of("d2-dmsgd");
    let dlam = bias_of("decentlam");
    assert!(d2 < dmsgd, "d2 {d2} vs dmsgd {dmsgd}");
    assert!(dlam < dmsgd, "decentlam {dlam} vs dmsgd {dmsgd}");
}

#[test]
fn schedule_decays_learning_rate_in_training() {
    let mut cfg = base_cfg("decentlam", 4, 90);
    cfg.schedule = LrSchedule::WarmupStep { warmup_steps: 5, milestones: vec![30, 60] };
    assert!(cfg.lr_at(0) < cfg.lr_at(4));
    assert!(cfg.lr_at(40) < cfg.lr_at(20));
    assert!(cfg.lr_at(70) < cfg.lr_at(40));
    let data = mlp_data(4, 1.0, 6);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 6);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.losses.iter().all(|l| l.is_finite()));
}

#[test]
fn experiment_harness_fig6_matches_paper_band() {
    let (rows, table) = exp::fig6::run(&exp::fig6::Opts::default()).unwrap();
    assert!(!rows.is_empty());
    let rendered = table.render();
    assert!(rendered.contains("decentlam"));
    // Headline claim: 1.2-1.9x at the paper's settings (10 Gbps, 2K).
    let r = rows
        .iter()
        .find(|r| r.method == "decentlam" && r.bandwidth_gbps == 10.0 && r.batch == 2048)
        .unwrap();
    assert!(
        (1.1..2.2).contains(&r.speedup_vs_pmsgd),
        "speedup {}",
        r.speedup_vs_pmsgd
    );
}

#[test]
fn wire_bytes_pinned_for_ring_grid_exp() {
    // Regression pins for the PR-1 cost model: exact per-iteration wire
    // bytes (2 · edges · payload for one neighbor exchange) at the edge
    // counts these topologies realize. A change to topology
    // construction or the byte accounting must show up here.
    let payload = PayloadBytes::uniform(1.0); // totals below are exact edge-count doubles
    let expected: [(Kind, usize, f64); 6] = [
        (Kind::Ring, 8, 16.0),    // 8 edges
        (Kind::Ring, 64, 128.0),  // 64 edges
        (Kind::Mesh, 8, 24.0),    // 2x4 torus: 8 horizontal + 4 vertical
        (Kind::Mesh, 64, 256.0),  // 8x8 torus: 128 edges
        (Kind::SymExp, 8, 40.0),  // hops 1,2,4: 20 edges
        (Kind::SymExp, 64, 704.0) // hops 1..32: 352 edges
    ];
    for (kind, n, want) in expected {
        let stats = CommStats::of_topology(&Topology::build(kind, n));
        let got = wire_bytes_per_iter(CommPattern::Neighbor { payloads: 1 }, &stats, payload);
        assert_eq!(got, want, "{kind:?} n={n}: {got} wire bytes, want {want}");
    }
}

#[test]
fn dsgd_gossip_consensus_monotone_on_static_ring() {
    // Pure gossip (zero gradients) under a doubly-stochastic W is a
    // contraction toward consensus: the consensus distance must never
    // increase round over round, and must shrink overall.
    let n = 8;
    let d = 6;
    let wm = metropolis_hastings(&Topology::build(Kind::Ring, n));
    let mut o = optim::build("dsgd", 1, 0.0).unwrap();
    let mut rng = decentlam::util::rng::Pcg64::seeded(31);
    let mut states: Vec<NodeState> = (0..n)
        .map(|_| {
            let mut x = vec![0.0f32; d];
            rng.normal_fill(&mut x, 1.0);
            NodeState::new(x, 0)
        })
        .collect();
    let grads = vec![vec![0.0f32; d]; n];
    let mut scratch = Scratch::new(n, d);
    let consensus = |states: &[NodeState]| -> f64 {
        let refs: Vec<&[f32]> = states.iter().map(|s| s.x.as_slice()).collect();
        let xbar = math::mean_of(&refs);
        states.iter().map(|s| math::dist2(&s.x, &xbar)).sum::<f64>() / n as f64
    };
    let mut prev = consensus(&states);
    let initial = prev;
    for step in 0..50 {
        let ctx = RoundCtx::new(&wm, 0.1, 0.0, step, false);
        o.round(&mut states, &grads, &ctx, &mut scratch);
        let now = consensus(&states);
        assert!(
            now <= prev + 1e-12,
            "consensus grew at round {step}: {prev} -> {now}"
        );
        prev = now;
    }
    assert!(
        prev < initial * 1e-3,
        "gossip barely contracted: {initial} -> {prev}"
    );
}

#[test]
fn failure_injection_bad_configs() {
    // Unknown optimizer.
    let mut cfg = base_cfg("adamw", 4, 5);
    let data = mlp_data(4, 1.0, 1);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
    assert!(Trainer::new(cfg.clone(), wl).is_err());
    // Unknown topology.
    cfg.optimizer = "dmsgd".into();
    cfg.topology = "hypercube-9d".into();
    let data = mlp_data(4, 1.0, 1);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 1);
    assert!(Trainer::new(cfg, wl).is_err());
}

#[test]
fn single_node_degenerates_to_sgd() {
    // n=1 ring: W = [1]; decentlam == plain momentum SGD; must train.
    let mut cfg = base_cfg("decentlam", 1, 100);
    cfg.total_batch = 64;
    let data = mlp_data(1, 100.0, 7);
    let wl = mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 32, 7);
    let mut t = Trainer::new(cfg, wl).unwrap();
    let r = t.run();
    assert!(r.final_accuracy > 0.5, "acc {}", r.final_accuracy);
    assert!(r.final_consensus < 1e-12);
}
