//! Run-profile observability properties (DESIGN.md §14):
//!
//! 1. `metrics` lines are deterministic — bitwise rerun-identical and
//!    parallel == serial — across the full optimizer roster;
//! 2. collection never perturbs the run: a metrics-on stream minus its
//!    `metrics` lines is byte-identical to the metrics-off stream, and
//!    the trajectories match bit for bit;
//! 3. profiled runs stay byte-identical after [`strip_timing`] (the
//!    `timing` class is the ONE nondeterministic event), and replay
//!    still certifies the report;
//! 4. the sink's flush cadence is invisible in the bytes;
//! 5. the committed `DLTEL01` golden stream parses forever, round-trips
//!    byte for byte, and rejects the DLTEL02-only observability events.

use std::path::{Path, PathBuf};

use decentlam::coordinator::{TrainReport, Trainer};
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::{mlp, Workload};
use decentlam::optim;
use decentlam::telemetry::{replay_path, replay_str, strip_timing, Event};
use decentlam::util::config::{Config, LrSchedule};

fn workload(nodes: usize, seed: u64) -> Workload {
    let data = ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 96,
        eval_samples: 128,
        dirichlet_alpha: 0.3,
        seed,
        ..Default::default()
    });
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 16, seed)
}

fn base_cfg(optimizer: &str) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = 4;
    cfg.steps = 6;
    cfg.total_batch = 64;
    cfg.micro_batch = 16;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg.eval_every = 3;
    cfg.threads = 1;
    cfg.seed = 7;
    cfg
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("decentlam_obs_{}_{name}", std::process::id()))
}

fn run_streamed(cfg: &Config, path: &Path) -> TrainReport {
    let mut cfg = cfg.clone();
    cfg.telemetry = Some(path.to_string_lossy().into_owned());
    let mut t = Trainer::new(cfg, workload(4, 7)).unwrap();
    let report = t.run();
    assert!(t.telemetry_error().is_none(), "sink went inert: {:?}", t.telemetry_error());
    report
}

/// The canonical wire form of a trainer's in-memory metrics log — the
/// bitwise object of comparison (struct `PartialEq` would treat NaN as
/// unequal to itself; the wire line maps it to `null`).
fn metrics_lines(t: &Trainer) -> Vec<String> {
    t.metrics_log().iter().map(|m| m.to_event().to_line()).collect()
}

#[test]
fn metrics_are_rerun_identical_and_par_eq_serial_across_all_optimizers() {
    for name in optim::ALL.iter().chain([&"dsgd"]) {
        let mut cfg = base_cfg(name);
        cfg.metrics_every = 2;
        let run = |threads: usize| {
            let mut cfg = cfg.clone();
            cfg.threads = threads;
            let mut t = Trainer::new(cfg, workload(4, 7)).unwrap();
            t.run();
            metrics_lines(&t)
        };
        let serial = run(1);
        assert_eq!(serial.len(), 3, "{name}: cadence every=2 over 6 steps");
        assert_eq!(serial, run(1), "{name}: rerun changed metrics bytes");
        assert_eq!(serial, run(0), "{name}: threading changed metrics bytes");
    }
}

#[test]
fn metrics_collection_never_perturbs_the_run() {
    let cfg = base_cfg("dmsgd");
    let off_path = tmp("perturb_off.jsonl");
    let on_path = tmp("perturb_on.jsonl");

    let off = run_streamed(&cfg, &off_path);
    let mut on_cfg = cfg.clone();
    on_cfg.metrics_every = 1;
    on_cfg.telemetry = Some(on_path.to_string_lossy().into_owned());
    let mut t = Trainer::new(on_cfg, workload(4, 7)).unwrap();
    let on = t.run();

    let bits = |ls: &[f64]| ls.iter().map(|l| l.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&on.losses), bits(&off.losses), "metrics collection moved the trajectory");
    assert_eq!(on.manifest, off.manifest, "metrics_every leaked into the manifest");

    // The on-stream minus its `metrics` lines IS the off-stream.
    let on_text = std::fs::read_to_string(&on_path).unwrap();
    let without: String =
        on_text.lines().filter(|l| !l.contains("\"event\":\"metrics\"")).fold(
            String::new(),
            |mut acc, l| {
                acc.push_str(l);
                acc.push('\n');
                acc
            },
        );
    assert_eq!(without, std::fs::read_to_string(&off_path).unwrap());

    // And the stream's metrics ARE the trainer's in-memory log.
    let r = replay_path(&on_path).unwrap();
    assert_eq!(r.metrics.len(), cfg.steps);
    assert_eq!(
        r.metrics.iter().map(|m| m.to_event().to_line()).collect::<Vec<_>>(),
        metrics_lines(&t)
    );
    std::fs::remove_file(&off_path).unwrap();
    std::fs::remove_file(&on_path).unwrap();
}

#[test]
fn profiled_streams_strip_to_byte_identity() {
    let mut cfg = base_cfg("decentlam");
    cfg.threads = 0; // profiled pool path: lane meters live
    cfg.metrics_every = 3;
    cfg.profile_every = 2;
    let a = tmp("profiled_a.jsonl");
    let b = tmp("profiled_b.jsonl");
    let live = run_streamed(&cfg, &a);
    run_streamed(&cfg, &b);

    let (ta, tb) = (std::fs::read_to_string(&a).unwrap(), std::fs::read_to_string(&b).unwrap());
    // `timing` is the one event class allowed to differ between runs.
    assert_ne!(strip_timing(&ta), ta, "no timing lines were streamed");
    assert_eq!(strip_timing(&ta), strip_timing(&tb), "profiled runs differ beyond timing");

    let r = replay_path(&a).unwrap();
    assert_eq!(r.version, "DLTEL02", "new streams must declare DLTEL02");
    assert!(r.complete);
    assert_eq!(r.timing_events, 3, "cadence every=2 over 6 steps");
    let Some(Event::Timing { grad_ns, lane_busy_ns, .. }) = &r.last_timing else {
        panic!("missing final timing event");
    };
    assert!(*grad_ns > 0, "grad phase never measured");
    assert!(!lane_busy_ns.is_empty() && lane_busy_ns.iter().sum::<u64>() > 0);
    assert_eq!(r.metrics.len(), 2, "metrics cadence every=3 over 6 steps");
    // Wall-clock riders never enter the report contract.
    r.matches_report(&live).unwrap();
    std::fs::remove_file(&a).unwrap();
    std::fs::remove_file(&b).unwrap();
}

#[test]
fn flush_cadence_is_invisible_in_the_bytes() {
    let mut cfg = base_cfg("decentlam");
    cfg.metrics_every = 2;
    let a = tmp("flush_default.jsonl");
    let b = tmp("flush_one.jsonl");
    run_streamed(&cfg, &a);
    let mut eager = cfg.clone();
    eager.apply_kv("telemetry", &format!("{},flush=1", b.to_string_lossy())).unwrap();
    let mut t = Trainer::new(eager, workload(4, 7)).unwrap();
    t.run();
    assert!(t.telemetry_error().is_none());
    assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    std::fs::remove_file(&a).unwrap();
    std::fs::remove_file(&b).unwrap();
}

#[test]
fn golden_dltel01_stream_parses_forever() {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/dltel01_golden.jsonl");
    let text = std::fs::read_to_string(&path).unwrap();

    // Every committed line round-trips byte for byte — including the
    // run-start, whose parsed version is preserved on re-serialize.
    for line in text.lines() {
        let ev = Event::parse_line(line).unwrap_or_else(|e| panic!("{line}: {e:#}"));
        assert_eq!(ev.to_line(), line, "non-canonical golden line");
    }

    let r = replay_str(&text).unwrap();
    assert_eq!(r.version, "DLTEL01");
    assert!(r.complete && !r.truncated);
    assert_eq!(r.report.losses, vec![2.5, 2.25]);
    assert_eq!(r.report.evals, vec![(2, 0.5)]);
    assert_eq!(r.report.wire_bytes_total, 200.0);
    assert_eq!(r.churn_events, 1);
    assert_eq!(r.checkpoints, vec![2]);
    let f = r.fault_totals.unwrap();
    assert_eq!(f.realized_edges + f.masked_edges, f.nominal_edges);
    assert!(r.metrics.is_empty() && r.timing_events == 0);

    // A legacy stream cannot smuggle the DLTEL02-only event classes.
    let metrics_line = Event::Metrics {
        step: 1,
        consensus_p50: 0.25,
        consensus_p95: 0.25,
        consensus_max: 0.25,
        consensus_hist: vec![(-2, 2)],
        momentum_disagreement: 0.0,
        bias_proxy: 0.0,
    }
    .to_line();
    let end = text.rfind("{\"event\":\"run-end\"").unwrap();
    let smuggled = format!("{}{metrics_line}\n{}", &text[..end], &text[end..]);
    let e = format!("{:#}", replay_str(&smuggled).unwrap_err());
    assert!(e.contains("`metrics` events require DLTEL02"), "{e}");
}
