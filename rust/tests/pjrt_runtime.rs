//! PJRT end-to-end tests: load the AOT artifacts, check the Pallas
//! update kernel against the native Rust mirror, cross-check the JAX
//! MLP gradients against the native engine, and run short decentralized
//! training through the PJRT path.
//!
//! All tests skip gracefully if `make artifacts` has not run.

use std::path::Path;

use decentlam::coordinator::Trainer;
use decentlam::data::corpus::Corpus;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::experiments::table6;
use decentlam::grad::{mlp, pjrt};
use decentlam::optim::decentlam::fused_apply;
use decentlam::runtime::{Manifest, Runtime, Tensor};
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::rng::Pcg64;

fn setup() -> Option<(Manifest, Runtime)> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping pjrt tests: run `make artifacts` first");
        return None;
    }
    let manifest = Manifest::load(dir).unwrap();
    let runtime = Runtime::start().unwrap();
    Some((manifest, runtime))
}

fn small_data(nodes: usize) -> ClassificationData {
    ClassificationData::generate(&SynthSpec {
        nodes,
        samples_per_node: 256,
        eval_samples: 256,
        dirichlet_alpha: 1.0,
        seed: 2,
        ..Default::default()
    })
}

#[test]
fn pallas_update_kernel_matches_native_fused_apply() {
    let Some((manifest, runtime)) = setup() else { return };
    let rt = runtime.handle();
    let info = manifest.model("mlp-s").unwrap();
    let d = info.dim;
    let kernel = manifest.update_kernel_for_dim(d).expect("kernel artifact");
    rt.load_artifact(&manifest, &kernel).unwrap();

    let kpad = 8usize;
    let mut rng = Pcg64::seeded(11);
    let mut z = vec![0.0f32; kpad * d];
    rng.normal_fill(&mut z, 1.0);
    // Stochastic weight row with 5 active neighbors, zero-padded.
    let w = vec![0.25f32, 0.25, 0.2, 0.2, 0.1, 0.0, 0.0, 0.0];
    let mut x = vec![0.0f32; d];
    let mut m = vec![0.0f32; d];
    rng.normal_fill(&mut x, 1.0);
    rng.normal_fill(&mut m, 1.0);
    let (gamma, beta) = (0.05f32, 0.9f32);

    let out = rt
        .exec(
            &kernel,
            vec![
                Tensor::f32(z.clone(), &[kpad as i64, d as i64]),
                Tensor::f32(w.clone(), &[kpad as i64]),
                Tensor::f32(x.clone(), &[d as i64]),
                Tensor::f32(m.clone(), &[d as i64]),
                Tensor::f32(vec![gamma, beta], &[2]),
            ],
        )
        .unwrap();
    assert_eq!(out.len(), 2);
    assert_eq!(out[0].len(), d);

    // Native mirror.
    let mut mix = vec![0.0f32; d];
    for k in 0..kpad {
        if w[k] != 0.0 {
            for j in 0..d {
                mix[j] += w[k] * z[k * d + j];
            }
        }
    }
    let (mut xn, mut mn) = (x.clone(), m.clone());
    fused_apply(&mut xn, &mut mn, &mix, gamma, beta);
    let mut max_dx = 0.0f32;
    let mut max_dm = 0.0f32;
    for j in 0..d {
        max_dx = max_dx.max((out[0][j] - xn[j]).abs());
        max_dm = max_dm.max((out[1][j] - mn[j]).abs());
    }
    assert!(max_dx < 1e-3, "kernel vs native x mismatch {max_dx}");
    assert!(max_dm < 2e-2, "kernel vs native m mismatch {max_dm}");
}

#[test]
fn jax_mlp_gradient_agrees_with_native_engine() {
    let Some((manifest, runtime)) = setup() else { return };
    let rt = runtime.handle();
    rt.load_artifact(&manifest, "mlp-s_grad").unwrap();
    let info = manifest.model("mlp-s").unwrap();
    let theta = manifest.load_init(&info).unwrap();
    let b = info.micro_batch;
    let dimx = info.input_dim;

    let mut rng = Pcg64::seeded(4);
    let mut xb = vec![0.0f32; b * dimx];
    rng.normal_fill(&mut xb, 1.0);
    let yb: Vec<i32> = (0..b).map(|i| (i % info.num_classes) as i32).collect();

    let out = rt
        .exec(
            "mlp-s_grad",
            vec![
                Tensor::f32(theta.clone(), &[info.dim as i64]),
                Tensor::f32(xb.clone(), &[b as i64, dimx as i64]),
                Tensor::i32(yb.clone(), &[b as i64]),
            ],
        )
        .unwrap();
    let (jax_loss, jax_grad) = (out[0][0] as f64, &out[1]);

    // Native engine on the same batch: drive fwd_bwd through a one-shot
    // shard by reusing the public workload API is awkward; instead use
    // finite differences as the neutral referee on a few coordinates.
    let arch = mlp::MlpArch::family("mlp-s").unwrap();
    assert_eq!(arch.dim(), info.dim, "layouts agree");
    assert!(jax_loss > 0.0 && jax_loss < 10.0);
    let loss_at = |t: &[f32]| -> f64 {
        let o = rt
            .exec(
                "mlp-s_grad",
                vec![
                    Tensor::f32(t.to_vec(), &[info.dim as i64]),
                    Tensor::f32(xb.clone(), &[b as i64, dimx as i64]),
                    Tensor::i32(yb.clone(), &[b as i64]),
                ],
            )
            .unwrap();
        o[0][0] as f64
    };
    let eps = 1e-2f32;
    for &k in &[0usize, 100, 9000, info.dim - 1] {
        let mut tp = theta.clone();
        tp[k] += eps;
        let mut tm = theta.clone();
        tm[k] -= eps;
        let fd = (loss_at(&tp) - loss_at(&tm)) / (2.0 * eps as f64);
        assert!(
            (fd - jax_grad[k] as f64).abs() < 2e-2 * (1.0 + fd.abs()),
            "coord {k}: fd {fd} vs jax {}",
            jax_grad[k]
        );
    }
}

#[test]
fn pjrt_decentralized_training_end_to_end() {
    let Some((manifest, runtime)) = setup() else { return };
    let rt = runtime.handle();
    let nodes = 4;
    let wl = pjrt::mlp_workload(&rt, &manifest, "mlp-s", small_data(nodes)).unwrap();
    let mut cfg = Config::default();
    cfg.optimizer = "decentlam".into();
    cfg.nodes = nodes;
    cfg.steps = 25;
    cfg.total_batch = 256;
    cfg.micro_batch = 64;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    let mut t = Trainer::new(cfg, wl).unwrap();
    let report = t.run();
    assert!(report.losses[0].is_finite());
    assert!(
        *report.losses.last().unwrap() < report.losses[0],
        "PJRT training did not descend: {:?}",
        &report.losses[..3]
    );
    assert!(report.final_accuracy > 0.2, "acc {}", report.final_accuracy);
}

#[test]
fn pjrt_lm_gradient_step_descends() {
    let Some((manifest, runtime)) = setup() else { return };
    let rt = runtime.handle();
    let corpus = Corpus::builtin();
    let mut wl = pjrt::lm_workload(&rt, &manifest, "lm-base", &corpus, 2).unwrap();
    let mut theta = wl.init.clone();
    let mut g = vec![0.0f32; wl.dim];
    let l0 = wl.nodes[0].grad_accum(&theta, 1, &mut g);
    // ~log(96) at init
    assert!((l0 - (96f64).ln()).abs() < 1.0, "init LM loss {l0}");
    for _ in 0..10 {
        wl.nodes[0].grad_accum(&theta, 1, &mut g);
        decentlam::util::math::axpy(&mut theta, -0.05, &g);
    }
    let l1 = wl.nodes[0].grad_accum(&theta, 1, &mut g);
    assert!(l1 < l0, "LM loss should descend: {l0} -> {l1}");
}

#[test]
fn table6_detection_analog_runs() {
    let Some((manifest, runtime)) = setup() else { return };
    let opts = table6::Opts {
        nodes: 4,
        steps: 8,
        total_batch: 256,
        methods: vec!["dmsgd".into(), "decentlam".into()],
        seed: 1,
    };
    let (cells, table) = table6::run(&runtime.handle(), &manifest, &opts).unwrap();
    assert_eq!(cells.len(), 2);
    assert!(cells.iter().all(|c| c.1.is_finite() && c.1 > 0.0));
    assert!(table.render().contains("mAP"));
}
