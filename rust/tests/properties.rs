//! Property-based tests over the coordinator's invariants (routing,
//! mixing, batching, state management) using the in-tree `prop` harness
//! (proptest substitute — see DESIGN.md §2).

use decentlam::comm::CommEngine;
use decentlam::coordinator::NodeExecutor;
use decentlam::optim::{
    self, partial_average_all, partial_average_all_par, NodeState, RoundCtx, Scratch,
};
use decentlam::prop::{check, gens};
use decentlam::topology::{metropolis_hastings, rho, Kind, SparseWeights, Topology};
use decentlam::util::math;
use decentlam::util::rng::Pcg64;

const STATIC_KINDS: [Kind; 5] =
    [Kind::Ring, Kind::Mesh, Kind::Full, Kind::Star, Kind::SymExp];

fn random_kind(rng: &mut Pcg64) -> Kind {
    STATIC_KINDS[rng.below(STATIC_KINDS.len())]
}

#[test]
fn prop_metropolis_weights_doubly_stochastic_any_graph() {
    check(
        "MH weights are symmetric doubly stochastic on any topology",
        60,
        |rng| (random_kind(rng), gens::nodes(rng)),
        |&(kind, n)| {
            let wm = metropolis_hastings(&Topology::at_step(kind, n, 7, 0));
            if wm.stochasticity_error() > 1e-9 {
                return Err(format!("row sums off by {}", wm.stochasticity_error()));
            }
            if wm.dense.asymmetry() > 1e-12 {
                return Err("asymmetric".into());
            }
            for i in 0..n {
                if wm.self_weight(i) <= 0.0 {
                    return Err(format!("w_{i}{i} <= 0"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_rho_strictly_below_one_on_connected_graphs() {
    check(
        "rho(W) in [0, 1) for connected topologies",
        40,
        |rng| (random_kind(rng), 2 + rng.below(13)),
        |&(kind, n)| {
            let wm = metropolis_hastings(&Topology::at_step(kind, n, 3, 0));
            let r = rho(&wm);
            if !(0.0..1.0 - 1e-9).contains(&r) {
                return Err(format!("rho = {r}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_partial_averaging_preserves_mean_and_contracts_spread() {
    check(
        "gossip preserves the network mean and never widens the spread",
        40,
        |rng| {
            let kind = random_kind(rng);
            let n = gens::nodes(rng);
            let d = 1 + rng.below(32);
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (kind, src)
        },
        |(kind, src)| {
            let n = src.len();
            let d = src[0].len();
            let wm = metropolis_hastings(&Topology::at_step(*kind, n, 1, 0));
            let mut dst = vec![vec![0.0f32; d]; n];
            partial_average_all(&wm, src, &mut dst);
            for j in 0..d {
                let before: f64 = src.iter().map(|r| r[j] as f64).sum();
                let after: f64 = dst.iter().map(|r| r[j] as f64).sum();
                if (before - after).abs() > 1e-3 * (1.0 + before.abs()) {
                    return Err(format!("mean moved: {before} -> {after}"));
                }
            }
            // Spread (max deviation from mean) must not grow.
            let spread = |rows: &[Vec<f32>]| -> f64 {
                let mut worst = 0.0f64;
                for j in 0..d {
                    let mean: f64 =
                        rows.iter().map(|r| r[j] as f64).sum::<f64>() / n as f64;
                    for r in rows {
                        worst = worst.max((r[j] as f64 - mean).abs());
                    }
                }
                worst
            };
            if spread(&dst) > spread(src) + 1e-6 {
                return Err("spread grew under gossip".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_every_optimizer_preserves_consensus_fixed_point() {
    // At consensus with zero gradients, NO optimizer may move the model
    // (state-management invariant of the coordinator).
    check(
        "consensus + zero grad is a fixed point for every optimizer",
        30,
        |rng| {
            let kind = random_kind(rng);
            let n = gens::nodes(rng);
            let d = 1 + rng.below(16);
            let x = gens::normal_vec(rng, d);
            let idx = rng.below(optim::ALL.len());
            (kind, n, x, idx)
        },
        |(kind, n, x, idx)| {
            let name = optim::ALL[*idx];
            let mut o = optim::build(name, 4, 0.7).unwrap();
            let wm = metropolis_hastings(&Topology::at_step(*kind, *n, 1, 0));
            let d = x.len();
            let mut states: Vec<NodeState> =
                (0..*n).map(|_| NodeState::new(x.clone(), o.aux_count())).collect();
            let grads = vec![vec![0.0f32; d]; *n];
            let mut scratch = Scratch::new(*n, d);
            for step in 0..5 {
                let ctx = RoundCtx::new(&wm, 0.1, 0.9, step, false);
                o.round(&mut states, &grads, &ctx, &mut scratch);
            }
            for (i, st) in states.iter().enumerate() {
                let drift = math::dist2(&st.x, x).sqrt();
                if drift > 1e-4 {
                    return Err(format!("{name}: node {i} drifted {drift}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_decentralized_rounds_preserve_network_mean_modulo_gradient() {
    // For doubly-stochastic mixing, one round moves the network average
    // exactly by -lr * (mean momentumized gradient) for DSGD (beta=0).
    check(
        "DSGD round moves the mean by -lr * mean gradient",
        30,
        |rng| {
            let n = gens::nodes(rng);
            let d = 1 + rng.below(8);
            let xs: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            let gs: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (n, xs, gs)
        },
        |(n, xs, gs)| {
            let d = xs[0].len();
            let wm = metropolis_hastings(&Topology::at_step(Kind::Ring, *n, 1, 0));
            let mut o = optim::build("dsgd", 1, 0.0).unwrap();
            let mut states: Vec<NodeState> =
                xs.iter().map(|x| NodeState::new(x.clone(), 0)).collect();
            let mut scratch = Scratch::new(*n, d);
            let lr = 0.05f32;
            let ctx = RoundCtx::new(&wm, lr, 0.0, 0, false);
            o.round(&mut states, gs, &ctx, &mut scratch);
            for j in 0..d {
                let mean_before: f64 =
                    xs.iter().map(|r| r[j] as f64).sum::<f64>() / *n as f64;
                let mean_grad: f64 =
                    gs.iter().map(|r| r[j] as f64).sum::<f64>() / *n as f64;
                let mean_after: f64 =
                    states.iter().map(|s| s.x[j] as f64).sum::<f64>() / *n as f64;
                let want = mean_before - lr as f64 * mean_grad;
                if (mean_after - want).abs() > 1e-4 * (1.0 + want.abs()) {
                    return Err(format!("dim {j}: {mean_after} vs {want}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_accumulator_mean_equals_manual_mean() {
    use decentlam::optim::schedule::GradAccumulator;
    check(
        "gradient accumulator computes the exact mean",
        40,
        |rng| {
            let d = 1 + rng.below(32);
            let k = 1 + rng.below(10);
            let grads: Vec<Vec<f32>> = (0..k).map(|_| gens::normal_vec(rng, d)).collect();
            grads
        },
        |grads| {
            let d = grads[0].len();
            let mut acc = GradAccumulator::new(d);
            for g in grads {
                acc.add(g);
            }
            let mut got = vec![0.0f32; d];
            acc.mean_into(&mut got);
            for j in 0..d {
                let want: f32 =
                    grads.iter().map(|g| g[j]).sum::<f32>() / grads.len() as f32;
                if (got[j] - want).abs() > 1e-5 {
                    return Err(format!("dim {j}: {} vs {want}", got[j]));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_and_dense_partial_averaging_agree() {
    // The tentpole invariant: the CSR neighbor-list engine and the
    // dense reference matrix compute the same exchange to 1e-6 on
    // random topologies (static AND time-varying realizations).
    check(
        "sparse and dense partial averaging agree to 1e-6",
        60,
        |rng| {
            let kind = Kind::ALL[rng.below(Kind::ALL.len())];
            let n = 2 + 2 * rng.below(8); // even, for bipartite matching
            let d = 1 + rng.below(24);
            let step = rng.below(50);
            let seed = rng.next_u64();
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (kind, n, step, seed, src)
        },
        |(kind, n, step, seed, src)| {
            let d = src[0].len();
            let topo = Topology::at_step(*kind, *n, *seed, *step);
            let dense = metropolis_hastings(&topo);
            let sparse = SparseWeights::metropolis_hastings(&topo);
            let mut out_dense = vec![vec![0.0f32; d]; *n];
            let mut out_sparse = vec![vec![0.0f32; d]; *n];
            partial_average_all(&dense, src, &mut out_dense);
            partial_average_all(&sparse, src, &mut out_sparse);
            for i in 0..*n {
                for k in 0..d {
                    let (a, b) = (out_dense[i][k], out_sparse[i][k]);
                    if (a - b).abs() > 1e-6 * (1.0 + a.abs()) {
                        return Err(format!("{kind:?} node {i} dim {k}: dense {a} sparse {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sparse_mh_rows_sum_to_one() {
    // Metropolis–Hastings rows (and their lazy transform) must stay
    // stochastic no matter which topology realization produced them.
    check(
        "sparse MH weight rows sum to 1 (plain and lazy)",
        60,
        |rng| {
            let kind = Kind::ALL[rng.below(Kind::ALL.len())];
            let n = 2 + 2 * rng.below(10);
            let step = rng.below(100);
            (kind, n, rng.next_u64(), step)
        },
        |&(kind, n, seed, step)| {
            let topo = Topology::at_step(kind, n, seed, step);
            let mut sw = SparseWeights::metropolis_hastings(&topo);
            if sw.row_sum_error() > 1e-6 {
                return Err(format!("{kind:?}: row sums off by {}", sw.row_sum_error()));
            }
            for i in 0..n {
                if sw.self_weight(i) <= 0.0 {
                    return Err(format!("{kind:?}: w_{i}{i} <= 0"));
                }
                if sw.row(i).iter().any(|&(_, w)| w < 0.0) {
                    return Err(format!("{kind:?}: negative weight in row {i}"));
                }
            }
            sw.make_lazy();
            if sw.row_sum_error() > 1e-6 {
                return Err(format!("{kind:?}: lazy row sums off by {}", sw.row_sum_error()));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_exchange_bitwise_matches_serial() {
    // The node executor chunks work but never reorders arithmetic:
    // parallel partial averaging must equal the serial result exactly.
    check(
        "parallel partial averaging is bitwise identical to serial",
        30,
        |rng| {
            let kind = random_kind(rng);
            let n = gens::nodes(rng);
            let d = 1 + rng.below(64);
            let threads = 2 + rng.below(7);
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            (kind, threads, src)
        },
        |(kind, threads, src)| {
            let n = src.len();
            let d = src[0].len();
            let sw = SparseWeights::metropolis_hastings(&Topology::at_step(*kind, n, 1, 0));
            let mut serial = vec![vec![0.0f32; d]; n];
            let mut parallel = vec![vec![0.0f32; d]; n];
            partial_average_all(&sw, src, &mut serial);
            partial_average_all_par(&sw, src, &mut parallel, &NodeExecutor::new(*threads));
            if serial != parallel {
                return Err("parallel result differs from serial".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_time_varying_topologies_deterministic_across_nodes() {
    // All nodes must realize the SAME graph at a step (deadlock freedom).
    check(
        "bipartite matching identical for identical (seed, step)",
        40,
        |rng| (4 + 2 * rng.below(7), rng.next_u64(), rng.below(1000)),
        |&(n, seed, step)| {
            let a = Topology::at_step(Kind::BipartiteRandomMatch, n, seed, step);
            let b = Topology::at_step(Kind::BipartiteRandomMatch, n, seed, step);
            for i in 0..n {
                if a.neighbors(i) != b.neighbors(i) {
                    return Err(format!("node {i} saw different graphs"));
                }
                if a.degree(i) != 1 {
                    return Err(format!("node {i} degree {} != 1", a.degree(i)));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_weighted_sum_into_matches_naive_sum_for_0_to_9_terms() {
    // Pins the pairwise-fused kernel against the naive Σ wᵢ·xᵢ for
    // every term count 0..=9 — both parities of the chunks_exact(2)
    // remainder path, including the empty-terms zero fill.
    check(
        "weighted_sum_into == naive sum, k in 0..=9",
        40,
        |rng| {
            let d = gens::dim(rng);
            let k = rng.below(10);
            let xs: Vec<Vec<f32>> = (0..k).map(|_| gens::normal_vec(rng, d)).collect();
            let ws: Vec<f32> = (0..k).map(|_| rng.f32() * 2.0 - 0.7).collect();
            (d, xs, ws)
        },
        |(d, xs, ws)| {
            let terms: Vec<(f32, &[f32])> =
                ws.iter().cloned().zip(xs.iter().map(|v| v.as_slice())).collect();
            let mut got = vec![3.25f32; *d]; // junk: must be overwritten
            math::weighted_sum_into(&mut got, &terms);
            for j in 0..*d {
                let naive: f32 = terms.iter().map(|(w, x)| w * x[j]).sum();
                if (got[j] - naive).abs() > 1e-4 {
                    return Err(format!(
                        "k={} dim {j}: fused {} vs naive {naive}",
                        terms.len(),
                        got[j]
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_int8_ef_residual_bounded_over_100_rounds() {
    // Codec round-trip error bound: with error feedback, the int8
    // residual reaches a steady state instead of accumulating. Inputs
    // bounded by M give a per-element steady-state error ≤ ~M/126, so
    // ‖r‖₂ stays well under √d·M/50 at every one of 100 rounds.
    use decentlam::comm::codec::{CodecSpec, CodecState};

    check(
        "int8+EF residual norm bounded over 100 rounds",
        8,
        |rng| {
            let d = 16 + rng.below(64);
            let scale = 0.5 + rng.f32() * 4.0;
            let seed = rng.next_u64();
            (d, scale, seed)
        },
        |&(d, scale, seed)| {
            let spec = CodecSpec::parse("int8,ef=true", seed).unwrap();
            let mut state = CodecState::new(&spec, 1, d);
            let mut rng = Pcg64::seeded(seed ^ 0xabcd);
            let mut src = vec![vec![0.0f32; d]];
            let bound = (d as f64).sqrt() * scale as f64 / 50.0;
            for step in 0..100 {
                for v in src[0].iter_mut() {
                    *v = (rng.f32() * 2.0 - 1.0) * scale;
                }
                state.begin_step(step);
                state.encode_round(&src, &NodeExecutor::serial());
                let norm = state.residual_norm(0, 0);
                if norm > bound {
                    return Err(format!("step {step}: ‖residual‖ = {norm} > {bound}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_codec_gossip_preserves_mean_within_quantization_error() {
    // Doubly-stochastic gossip preserves the network mean exactly;
    // through a lossy codec the drift is bounded by the per-element
    // quantization error, and the fp32 codec drifts not at all.
    check(
        "codec gossip mean drift bounded by quantization error",
        25,
        |rng| {
            let kind = random_kind(rng);
            let n = gens::nodes(rng);
            let d = gens::dim(rng);
            let src: Vec<Vec<f32>> = (0..n).map(|_| gens::normal_vec(rng, d)).collect();
            let seed = rng.next_u64();
            (kind, n, d, src, seed)
        },
        |&(kind, n, d, ref src, seed)| {
            use decentlam::comm::codec::{CodecSpec, CodecState};
            let sw = SparseWeights::metropolis_hastings(&Topology::at_step(kind, n, 5, 0));
            let mut dst = vec![vec![0.0f32; d]; n];
            for codec in ["fp32", "int8,ef=true"] {
                let spec = CodecSpec::parse(codec, seed).unwrap();
                let mut state = CodecState::new(&spec, n, d);
                state.begin_step(0);
                let wire: Vec<Vec<f32>> = if state.is_identity() {
                    src.clone()
                } else {
                    state.encode_round(src, &NodeExecutor::serial()).to_vec()
                };
                partial_average_all(&sw, &wire, &mut dst);
                let maxabs = src
                    .iter()
                    .flat_map(|r| r.iter())
                    .fold(0.0f32, |m, v| m.max(v.abs()));
                // Each wire element is within one quantum of its source.
                let tol = if codec == "fp32" { 1e-5 } else { maxabs as f64 / 127.0 + 1e-5 };
                for j in 0..d {
                    let before: f64 =
                        src.iter().map(|r| r[j] as f64).sum::<f64>() / n as f64;
                    let after: f64 =
                        dst.iter().map(|r| r[j] as f64).sum::<f64>() / n as f64;
                    if (before - after).abs() > tol {
                        return Err(format!(
                            "{codec} {kind:?} n={n} dim {j}: mean drift {} > {tol}",
                            (before - after).abs()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}
