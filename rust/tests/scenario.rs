//! Scenario registry integration tests (DESIGN.md §10): manifest
//! round-trips across every optimizer, CLI/manifest parity (the
//! zero-behavior-change pin for the config redesign), and the
//! checked-in `scenarios/` corpus.

use std::path::{Path, PathBuf};

use decentlam::coordinator::Trainer;
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::mlp;
use decentlam::optim;
use decentlam::scenario::{run_corpus, RunOpts, Status, TierFilter};
use decentlam::util::cli::Args;
use decentlam::util::config::{Config, LrSchedule};
use decentlam::util::json::Cursor;

fn corpus_dir() -> PathBuf {
    // tests run with CWD = rust/; the corpus lives at the repo root.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap().join("scenarios")
}

fn roundtrip(cfg: &Config) -> Config {
    let v = cfg.to_manifest();
    Config::from_manifest(&Cursor::root(&v, "config"))
        .unwrap_or_else(|e| panic!("reparsing own manifest failed: {e:#}\n{}", v.to_string()))
}

#[test]
fn manifest_roundtrips_for_every_optimizer_and_spec_combo() {
    for name in optim::ALL.iter().chain([&"dsgd"]) {
        let mut cfg = Config::default();
        cfg.optimizer = name.to_string();
        cfg.steps = 20;
        assert_eq!(roundtrip(&cfg), cfg, "{name}: plain config");
        // All four subsystem specs at once. Round-tripping is purely a
        // (de)serialization property — whether the combination RUNS is
        // validate()'s job, exercised by the rejected-combo corpus.
        cfg.apply_kv("faults", "drop=0.1,straggle=0.05,stale=0.5,seed=9").unwrap();
        cfg.apply_kv("codec", "int8,ef=true,seed=11").unwrap();
        cfg.apply_kv("async", "tau=2,spread=4,jitter=0.2").unwrap();
        cfg.apply_kv("churn", "join=0.02,leave=0.02,nmin=2,nmax=16,seed=3").unwrap();
        assert_eq!(roundtrip(&cfg), cfg, "{name}: all specs composed");
        // Clearing a spec drops it from the manifest again.
        cfg.apply_kv("codec", "").unwrap();
        assert!(cfg.codec.is_none());
        assert_eq!(roundtrip(&cfg), cfg, "{name}: cleared codec");
    }
}

#[test]
fn every_schedule_form_roundtrips_structurally() {
    for schedule in [
        LrSchedule::Constant,
        LrSchedule::WarmupStep { warmup_steps: 5, milestones: vec![40, 80] },
        LrSchedule::WarmupCosine { warmup_steps: 10, total_steps: 120 },
    ] {
        let mut cfg = Config::default();
        cfg.schedule = schedule;
        assert_eq!(roundtrip(&cfg), cfg);
    }
}

#[test]
fn seed_survives_beyond_f64_integer_range() {
    let mut cfg = Config::default();
    cfg.seed = u64::MAX - 1; // not representable exactly as f64
    assert_eq!(roundtrip(&cfg).seed, cfg.seed);
}

/// The zero-CLI-behavior-change pin: a config built from flags and the
/// same config round-tripped through its manifest drive bitwise
/// identical runs (same `TrainReport.manifest`, same loss trajectory).
#[test]
fn flag_built_and_manifest_built_configs_run_identically() {
    let argv = [
        "--nodes", "4", "--topology", "ring", "--optimizer", "decentlam",
        "--model", "mlp-xs", "--steps", "6", "--batch", "64", "--micro-batch", "16",
        "--lr", "0.05", "--linear-scaling", "false", "--schedule", "constant",
        "--threads", "1", "--seed", "7", "--faults", "drop=0.1,seed=3",
        "--codec", "int8,ef=true",
    ];
    let args = Args::parse(argv.iter().map(|s| s.to_string()));
    let flag_cfg = Config::from_args(&args).unwrap();
    let man_cfg = roundtrip(&flag_cfg);
    assert_eq!(man_cfg, flag_cfg);

    let run = |cfg: &Config| {
        let data = ClassificationData::generate(&SynthSpec {
            nodes: cfg.nodes,
            samples_per_node: 64,
            eval_samples: 64,
            seed: cfg.seed,
            ..Default::default()
        });
        let wl =
            mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, cfg.micro_batch, cfg.seed);
        let mut t = Trainer::new(cfg.clone(), wl).unwrap();
        let r = t.run();
        (r.manifest, r.losses)
    };
    let (manifest_a, losses_a) = run(&flag_cfg);
    let (manifest_b, losses_b) = run(&man_cfg);
    assert_eq!(manifest_a, manifest_b);
    assert_eq!(losses_a, losses_b);
}

/// The PR gate: every smoke-tier scenario in the checked-in corpus
/// passes (runnable ones descend + replay, rejected ones pin their
/// exact boundary error).
#[test]
fn smoke_corpus_passes() {
    let opts = RunOpts { tier: TierFilter::Smoke, ..Default::default() };
    let summary = run_corpus(&corpus_dir(), &opts).unwrap();
    assert!(!summary.outcomes.is_empty(), "smoke tier selected nothing");
    for o in &summary.outcomes {
        if let Status::Fail(why) = &o.status {
            panic!("scenario `{}` failed: {why}\n{}", o.name, summary.table().render());
        }
    }
    // The corpus must keep exercising both claim kinds.
    assert!(summary.outcomes.iter().any(|o| o.status == Status::Pass));
    assert!(summary.outcomes.iter().any(|o| o.status == Status::RejectedAsPinned));
}

/// Nightly tier — run with `cargo test -- --ignored`.
#[test]
#[ignore = "full tier is the nightly corpus gate (longer runs)"]
fn full_corpus_passes() {
    let summary = run_corpus(&corpus_dir(), &RunOpts::default()).unwrap();
    assert_eq!(summary.failed(), 0, "\n{}", summary.table().render());
}

#[test]
fn corpus_filter_and_tier_skip_counting() {
    let opts = RunOpts {
        tier: TierFilter::Smoke,
        filter: Some("reject-".to_string()),
        pin: false,
    };
    let summary = run_corpus(&corpus_dir(), &opts).unwrap();
    assert!(summary.outcomes.iter().all(|o| o.name.starts_with("reject-")));
    assert!(summary.skipped > 0, "filter should skip the runnable scenarios");
    let json = summary.to_json().to_string();
    assert!(json.contains("rejected-as-pinned"), "{json}");
}
