//! Telemetry bus + offline replay property suite (DESIGN.md §11),
//! across the full optimizer roster × realization layers:
//!
//! 1. every emitted line round-trips `parse_line ∘ to_line` **byte for
//!    byte** (canonical serialization);
//! 2. replaying the stream alone reconstructs the live
//!    [`TrainReport`] exactly ([`Replay::matches_report`]);
//! 3. telemetry OFF is bitwise identical to telemetry ON — the stream
//!    observes the run, never perturbs it;
//! 4. two identical runs produce byte-identical stream files;
//! 5. a crash-truncated tail is tolerated; mid-stream corruption is a
//!    hard error.

use std::path::PathBuf;

use decentlam::coordinator::{TrainReport, Trainer};
use decentlam::data::synth::{ClassificationData, SynthSpec};
use decentlam::grad::{mlp, Workload};
use decentlam::optim;
use decentlam::telemetry::{replay_path, replay_str, Event};
use decentlam::util::config::{Config, LrSchedule};

fn workload(capacity: usize, seed: u64) -> Workload {
    let data = ClassificationData::generate(&SynthSpec {
        nodes: capacity,
        samples_per_node: 96,
        eval_samples: 128,
        dirichlet_alpha: 0.3,
        seed,
        ..Default::default()
    });
    mlp::workload(mlp::MlpArch::family("mlp-xs").unwrap(), data, 16, seed)
}

fn base_cfg(optimizer: &str) -> Config {
    let mut cfg = Config::default();
    cfg.optimizer = optimizer.into();
    cfg.nodes = 4;
    cfg.steps = 6;
    cfg.total_batch = 64;
    cfg.micro_batch = 16;
    cfg.lr = 0.05;
    cfg.linear_scaling = false;
    cfg.momentum = 0.9;
    cfg.schedule = LrSchedule::Constant;
    cfg.topology = "ring".into();
    cfg.eval_every = 3;
    cfg.threads = 1;
    cfg.seed = 7;
    cfg
}

/// The four realization layers the stream must cover. Returns the
/// configured run + the stable-id capacity its workload needs, or None
/// when the combination is rejected by design (slowmo's periodic
/// all-reduce is a barrier `--async` refuses to model).
fn mode_cfg(optimizer: &str, mode: &str) -> Option<(Config, usize)> {
    let mut cfg = base_cfg(optimizer);
    let kv = match mode {
        "faults" => ("faults", "drop=0.1,straggle=0.1,stale=0.5,seed=3"),
        "codec" => ("codec", "int8,ef=true,seed=11"),
        "async" => {
            if optimizer == "slowmo" {
                return None;
            }
            ("async", "tau=2,spread=4,seed=5")
        }
        "churn" => ("churn", "join=0.1,leave=0.1,nmin=2,nmax=6,seed=5"),
        other => panic!("unknown mode {other}"),
    };
    cfg.apply_kv(kv.0, kv.1).unwrap();
    let capacity = if mode == "churn" { 6 } else { cfg.nodes };
    Some((cfg, capacity))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("decentlam_telemetry_{}_{name}", std::process::id()))
}

fn run_with_stream(cfg: &Config, capacity: usize, path: &PathBuf) -> TrainReport {
    let mut cfg = cfg.clone();
    cfg.telemetry = Some(path.to_string_lossy().into_owned());
    let mut t = Trainer::new(cfg, workload(capacity, 7)).unwrap();
    let report = t.run();
    assert!(t.telemetry_error().is_none(), "sink went inert: {:?}", t.telemetry_error());
    report
}

#[test]
fn all_optimizers_x_layers_round_trip_replay_and_off_identity() {
    for opt in optim::ALL.iter().chain([&"dsgd"]) {
        for mode in ["faults", "codec", "async", "churn"] {
            let Some((cfg, capacity)) = mode_cfg(opt, mode) else { continue };
            let path = tmp(&format!("{opt}_{mode}.jsonl"));
            let live = run_with_stream(&cfg, capacity, &path);

            // (1) Canonical per-line byte round trip.
            let text = std::fs::read_to_string(&path).unwrap();
            assert!(text.ends_with('\n'), "{opt}/{mode}: unterminated stream");
            for line in text.lines() {
                let ev = Event::parse_line(line)
                    .unwrap_or_else(|e| panic!("{opt}/{mode}: {line}: {e:#}"));
                assert_eq!(ev.to_line(), line, "{opt}/{mode}: non-canonical line");
            }

            // (2) The stream alone reconstructs the live summary.
            let r = replay_path(&path).unwrap();
            assert!(r.complete && !r.truncated, "{opt}/{mode}");
            r.matches_report(&live)
                .unwrap_or_else(|e| panic!("{opt}/{mode}: replay mismatch: {e:#}"));
            assert_eq!(r.report.losses.len(), cfg.steps, "{opt}/{mode}");
            if mode == "async" {
                assert!(r.async_event.is_some(), "{opt}/{mode}: async summary missing");
            }

            // (3) Telemetry off is bitwise identical: the bus observes,
            // never perturbs.
            let mut t = Trainer::new(cfg.clone(), workload(capacity, 7)).unwrap();
            let off = t.run();
            let bits = |ls: &[f64]| ls.iter().map(|l| l.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&off.losses), bits(&live.losses), "{opt}/{mode}: losses drifted");
            assert_eq!(
                off.final_consensus.to_bits(),
                live.final_consensus.to_bits(),
                "{opt}/{mode}"
            );
            assert_eq!(
                off.wire_bytes_total.to_bits(),
                live.wire_bytes_total.to_bits(),
                "{opt}/{mode}"
            );
            assert_eq!(off.manifest, live.manifest, "{opt}/{mode}: manifest drifted");

            std::fs::remove_file(&path).unwrap();
        }
    }
}

#[test]
fn fault_runs_stream_their_realizations() {
    // High rates so the seeded plan realizes faults with near-certainty
    // (the matrix test above covers the subtle-rate composition).
    let mut cfg = base_cfg("decentlam");
    cfg.steps = 10;
    cfg.apply_kv("faults", "drop=0.3,straggle=0.3,stale=0.5,seed=3").unwrap();
    let path = tmp("fault_events.jsonl");
    let live = run_with_stream(&cfg, 4, &path);
    let r = replay_path(&path).unwrap();
    r.matches_report(&live).unwrap();
    // Whatever was realized, the replayed per-step deltas must sum to
    // an internally consistent total: every nominal edge either carried
    // a message or was masked.
    let f = r.fault_totals.expect("no fault events streamed");
    assert!(f.steps > 0 && f.steps <= cfg.steps);
    assert_eq!(f.realized_edges + f.masked_edges, f.nominal_edges);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn churn_runs_stream_membership_events() {
    let mut cfg = base_cfg("decentlam");
    cfg.steps = 12;
    cfg.apply_kv("churn", "join=0.4,leave=0.4,nmin=2,nmax=6,seed=5").unwrap();
    let path = tmp("churn_events.jsonl");
    let live = run_with_stream(&cfg, 6, &path);
    let r = replay_path(&path).unwrap();
    r.matches_report(&live).unwrap();
    // join=leave=0.4 over 12 steps realizes membership motion with
    // near-certainty under any seed.
    assert!(r.churn_events > 0, "no churn events streamed");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn two_identical_runs_write_byte_identical_streams() {
    let (cfg, capacity) = mode_cfg("decentlam", "faults").unwrap();
    let a = tmp("bytes_a.jsonl");
    let b = tmp("bytes_b.jsonl");
    run_with_stream(&cfg, capacity, &a);
    run_with_stream(&cfg, capacity, &b);
    assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
    std::fs::remove_file(&a).unwrap();
    std::fs::remove_file(&b).unwrap();
}

#[test]
fn truncated_tail_is_tolerated_mid_stream_corruption_is_not() {
    let (cfg, capacity) = mode_cfg("decentlam", "codec").unwrap();
    let path = tmp("truncate.jsonl");
    run_with_stream(&cfg, capacity, &path);
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).unwrap();

    // Chop the final line mid-JSON at every cut depth a crash could
    // leave: the torn tail is dropped, the rest replays.
    let body_end = text[..text.len() - 1].rfind('\n').unwrap() + 1;
    for cut in [body_end + 1, body_end + 10, text.len() - 2] {
        let r = replay_str(&text[..cut]).unwrap_or_else(|e| panic!("cut {cut}: {e:#}"));
        assert!(r.truncated && !r.complete, "cut {cut}");
        assert_eq!(r.report.losses.len(), cfg.steps, "cut {cut}");
    }
    // Even cutting several whole lines back just shortens the summary.
    let shorter = &text[..text[..body_end - 1].rfind('\n').unwrap() + 1];
    let r = replay_str(shorter).unwrap();
    assert!(!r.complete && !r.truncated);

    // But the SAME corruption mid-stream is a hard error naming the line.
    let mut lines: Vec<&str> = text.lines().collect();
    let torn = &lines[2][..lines[2].len() - 5];
    lines[2] = torn;
    let corrupted = lines.join("\n") + "\n";
    let e = format!("{:#}", replay_str(&corrupted).unwrap_err());
    assert!(e.starts_with("telemetry line 3:"), "{e}");
}

#[test]
fn checkpoints_are_streamed() {
    let (cfg, capacity) = mode_cfg("decentlam", "faults").unwrap();
    let stream = tmp("ckpt.jsonl");
    let snap = tmp("ckpt.bin");
    let mut cfg = cfg;
    cfg.telemetry = Some(stream.to_string_lossy().into_owned());
    let mut t = Trainer::new(cfg.clone(), workload(capacity, 7)).unwrap();
    for k in 0..3 {
        t.step(k);
    }
    t.checkpoint_to(&snap).unwrap();
    drop(t); // flush on drop
    let r = replay_str(&std::fs::read_to_string(&stream).unwrap()).unwrap();
    assert!(!r.complete, "no run-end was written");
    assert_eq!(r.checkpoints, vec![3]);
    assert_eq!(r.report.losses.len(), 3);
    std::fs::remove_file(&stream).unwrap();
    std::fs::remove_file(&snap).unwrap();
}
