//! Vendored minimal re-implementation of the `anyhow` API surface that
//! the `decentlam` crate uses: [`Error`], [`Result`], the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros, and the [`Context`] extension
//! trait. Keeping it in-tree makes `cargo build` hermetic (no network,
//! no registry) — see DESIGN.md §Build. Swap the path dependency in
//! `rust/Cargo.toml` for the crates.io `anyhow = "1"` to get the real
//! thing; the API used here is a strict subset.

use std::fmt;

/// Drop-in subset of `anyhow::Error`: an error message plus a context
/// chain. `{}` displays the outermost message, `{:#}` the full chain
/// joined by `: ` (matching anyhow's alternate formatting).
pub struct Error {
    /// chain[0] is the outermost (most recently attached) message.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach an outer context layer (what `Context::context` uses).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if f.alternate() {
            for cause in &self.chain[1..] {
                write!(f, ": {cause}")?;
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain[0])?;
        if self.chain.len() > 1 {
            f.write_str("\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Like real anyhow, `Error` deliberately does NOT implement
// `std::error::Error`; that is what makes this blanket `From` legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option` (the two impls real anyhow provides).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(::std::format!("{}", $err))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// Early-return with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// `bail!` unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")?;
        Ok(s)
    }

    #[test]
    fn from_std_error_and_context_chain() {
        let err = fails_io().context("loading config").unwrap_err();
        let display = format!("{err}");
        assert_eq!(display, "loading config");
        let full = format!("{err:#}");
        assert!(full.starts_with("loading config: "), "{full}");
        assert!(err.chain().count() >= 2);
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
        let name = "x";
        let e = anyhow!("missing `{name}` ({})", 7);
        assert_eq!(format!("{e}"), "missing `x` (7)");

        fn inner(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {flag}");
            if !flag {
                bail!("unreachable");
            }
            Ok(1)
        }
        assert!(inner(true).is_ok());
        assert_eq!(format!("{}", inner(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn debug_shows_cause_chain() {
        let err = fails_io().with_context(|| format!("step {}", 2)).unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("step 2"));
        assert!(dbg.contains("Caused by"));
    }
}
