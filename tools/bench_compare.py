#!/usr/bin/env python3
"""Perf-trajectory comparator for the CI BENCH artifacts.

The bench harness (`rust/src/util/bench.rs`) dumps one JSON object per
target, keyed by case name, with `median_ns` as the headline statistic.
CI merges the per-target dumps into one `BENCH_<PR>.json`, uploads it,
and on the next run compares the fresh numbers against the previous
successful main-branch artifact (falling back to the committed
`BENCH_baseline.json` when no artifact is reachable). Regressions on
the pinned allowlist warn at >15% and fail at >30% — so a 2x mix-kernel
slowdown can no longer merge green.

Subcommands:
  merge OUT IN...            merge bench JSON objects; duplicate case
                             names are a hard error (the old `jq -s
                             add` silently let the last file win)
  compare CURRENT            gate CURRENT against a baseline:
      --baseline PATH        preferred baseline (may be absent)
      --fallback PATH        used when --baseline is absent (must exist)
      --allowlist PATH       case-name substrings under the gate
                             (default tools/bench_allowlist.txt)
      --warn PCT --fail PCT  thresholds (default 15 / 30)
  self-test                  exercise the comparator on synthetic data
                             (run in CI: proves a >30% regression fails)

Baselines whose `_meta` object carries `"provisional": true` (the
seeded `BENCH_baseline.json` — numbers typed in, not measured on the CI
runner) downgrade failures to warnings; the gate arms itself the first
time a real measured artifact becomes the baseline. Keys starting with
`_` are metadata, never benchmark cases.

Exit codes: 0 ok (warnings allowed), 1 failed regression or bad input.
"""

import argparse
import json
import os
import sys


def log(msg):
    print(msg, flush=True)


def die(msg):
    log(f"::error::{msg}")
    sys.exit(1)


def load_json(path):
    try:
        with open(path, "r", encoding="utf-8") as fh:
            data = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        die(f"{path}: cannot read bench JSON: {e}")
    if not isinstance(data, dict):
        die(f"{path}: bench JSON must be an object keyed by case name")
    return data


def cases_of(data):
    """Benchmark cases only: `_`-prefixed keys are metadata."""
    return {k: v for k, v in data.items() if not k.startswith("_")}


def median_of(path, name, entry):
    if not isinstance(entry, dict) or "median_ns" not in entry:
        die(f"{path}: case {name!r} has no median_ns")
    value = entry["median_ns"]
    if not isinstance(value, (int, float)) or value <= 0:
        die(f"{path}: case {name!r} has non-positive median_ns {value!r}")
    return float(value)


def load_allowlist(path):
    patterns = []
    try:
        with open(path, "r", encoding="utf-8") as fh:
            for line in fh:
                line = line.strip()
                if line and not line.startswith("#"):
                    patterns.append(line)
    except OSError as e:
        die(f"{path}: cannot read allowlist: {e}")
    if not patterns:
        die(f"{path}: allowlist is empty — the gate would cover nothing")
    return patterns


def allowlisted(name, patterns):
    return any(p in name for p in patterns)


def cmd_merge(args):
    merged = {}
    origin = {}
    for path in args.inputs:
        for name, entry in load_json(path).items():
            if name in merged and not name.startswith("_"):
                die(
                    f"duplicate bench case {name!r} in {path} "
                    f"(already defined by {origin[name]}) — case names must be "
                    f"unique across targets or the trajectory silently forks"
                )
            merged[name] = entry
            origin[name] = path
    with open(args.out, "w", encoding="utf-8") as fh:
        json.dump(merged, fh, indent=2, sort_keys=True)
        fh.write("\n")
    log(f"merged {len(cases_of(merged))} cases from {len(args.inputs)} files into {args.out}")
    return 0


def compare_data(current, baseline, patterns, warn_pct, fail_pct, provisional):
    """Pure comparison; returns (lines, warnings, failures) for testability."""
    lines, warnings, failures = [], [], []
    cur = cases_of(current)
    base = cases_of(baseline)
    gated = sorted(n for n in cur if allowlisted(n, patterns))
    for name in gated:
        if name not in base:
            lines.append(f"NEW    {name}: no baseline entry (joins the trajectory now)")
            continue
        b = median_of("<baseline>", name, base[name])
        c = median_of("<current>", name, cur[name])
        delta = (c - b) / b * 100.0
        tag = "ok"
        if delta > fail_pct:
            tag = "FAIL"
            (warnings if provisional else failures).append(
                f"{name}: median {b:.0f} -> {c:.0f} ns ({delta:+.1f}% > {fail_pct}%)"
            )
        elif delta > warn_pct:
            tag = "warn"
            warnings.append(
                f"{name}: median {b:.0f} -> {c:.0f} ns ({delta:+.1f}% > {warn_pct}%)"
            )
        lines.append(f"{tag:<6} {name}: {b:.0f} -> {c:.0f} ns ({delta:+.1f}%)")
    # Allowlisted coverage that vanished: a deleted case can hide a
    # regression as effectively as a slow one.
    for name in sorted(base):
        if allowlisted(name, patterns) and name not in cur:
            warnings.append(f"{name}: allowlisted case missing from current run")
    return lines, warnings, failures


def cmd_compare(args):
    current = load_json(args.current)
    if os.path.exists(args.baseline):
        base_path = args.baseline
        log(f"baseline: {base_path} (previous main-branch artifact)")
    else:
        base_path = args.fallback
        log(f"baseline: {base_path} (fallback — no previous artifact reachable)")
        if not os.path.exists(base_path):
            die(f"neither baseline {args.baseline} nor fallback {args.fallback} exists")
    baseline = load_json(base_path)
    meta = baseline.get("_meta", {})
    provisional = isinstance(meta, dict) and bool(meta.get("provisional"))
    if provisional:
        log(
            "::warning::baseline is PROVISIONAL (seeded, not measured on this "
            "runner): >30% regressions downgrade to warnings until the first "
            "real main-branch BENCH artifact becomes the baseline"
        )
    patterns = load_allowlist(args.allowlist)
    lines, warnings, failures = compare_data(
        current, baseline, patterns, args.warn, args.fail, provisional
    )
    for line in lines:
        log(line)
    if not lines:
        log("::warning::no allowlisted cases found in the current run")
    for w in warnings:
        log(f"::warning::bench regression: {w}")
    for f in failures:
        log(f"::error::bench regression: {f}")
    log(
        f"compared {len(lines)} allowlisted cases: "
        f"{len(failures)} failed, {len(warnings)} warned"
    )
    return 1 if failures else 0


def entry(median):
    return {"median_ns": median}


def cmd_self_test(_args):
    patterns = ["sparse exchange", "fleet_scaling", " round (n="]
    base = {
        "_meta": {"note": "synthetic"},
        "sparse exchange n=256": entry(1000.0),
        "fleet_scaling ring n=4096 pool": entry(2000.0),
        "decentlam round (n=8) d=17226": entry(500.0),
        "unrelated case": entry(100.0),
    }

    # 1. A 35% regression on an allowlisted case fails.
    cur = dict(base)
    cur["sparse exchange n=256"] = entry(1350.0)
    _, _, failures = compare_data(cur, base, patterns, 15, 30, False)
    assert len(failures) == 1 and "sparse exchange n=256" in failures[0], failures

    # 2. A 20% regression warns but does not fail.
    cur = dict(base)
    cur["fleet_scaling ring n=4096 pool"] = entry(2400.0)
    _, warnings, failures = compare_data(cur, base, patterns, 15, 30, False)
    assert not failures and len(warnings) == 1, (warnings, failures)

    # 3. A 35% regression on a NON-allowlisted case passes clean.
    cur = dict(base)
    cur["unrelated case"] = entry(135.0)
    _, warnings, failures = compare_data(cur, base, patterns, 15, 30, False)
    assert not failures and not warnings, (warnings, failures)

    # 4. Provisional baseline downgrades the failure to a warning.
    cur = dict(base)
    cur["sparse exchange n=256"] = entry(1350.0)
    _, warnings, failures = compare_data(cur, base, patterns, 15, 30, True)
    assert not failures and len(warnings) == 1, (warnings, failures)

    # 5. An improvement is quiet.
    cur = dict(base)
    cur["sparse exchange n=256"] = entry(400.0)
    _, warnings, failures = compare_data(cur, base, patterns, 15, 30, False)
    assert not failures and not warnings, (warnings, failures)

    # 6. A vanished allowlisted case warns (coverage loss).
    cur = dict(base)
    del cur["decentlam round (n=8) d=17226"]
    _, warnings, failures = compare_data(cur, base, patterns, 15, 30, False)
    assert not failures and any("missing" in w for w in warnings), (warnings, failures)

    # 7. Metadata keys are never compared as cases.
    lines, _, _ = compare_data(base, base, ["_meta"], 15, 30, False)
    assert not lines, lines

    # 8. merge rejects duplicate case names across inputs.
    import tempfile

    with tempfile.TemporaryDirectory() as tmp:
        a, b = os.path.join(tmp, "a.json"), os.path.join(tmp, "b.json")
        for path in (a, b):
            with open(path, "w", encoding="utf-8") as fh:
                json.dump({"dup case": entry(1.0)}, fh)
        out = os.path.join(tmp, "out.json")
        rc = os.spawnl(
            os.P_WAIT, sys.executable, sys.executable, __file__, "merge", out, a, b
        )
        assert rc != 0, "merge must reject duplicate case names"

    log("self-test: all comparator checks passed (incl. >30% synthetic failure)")
    return 0


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_merge = sub.add_parser("merge", help="merge bench JSON files")
    p_merge.add_argument("out")
    p_merge.add_argument("inputs", nargs="+")
    p_merge.set_defaults(func=cmd_merge)

    p_cmp = sub.add_parser("compare", help="gate current medians against a baseline")
    p_cmp.add_argument("current")
    p_cmp.add_argument("--baseline", required=True)
    p_cmp.add_argument("--fallback", required=True)
    p_cmp.add_argument("--allowlist", default="tools/bench_allowlist.txt")
    p_cmp.add_argument("--warn", type=float, default=15.0)
    p_cmp.add_argument("--fail", type=float, default=30.0)
    p_cmp.set_defaults(func=cmd_compare)

    p_st = sub.add_parser("self-test", help="synthetic comparator checks")
    p_st.set_defaults(func=cmd_self_test)

    args = parser.parse_args()
    sys.exit(args.func(args))


if __name__ == "__main__":
    main()
