//! The violation ratchet baseline: `xtask/lint-baseline.json`.
//!
//! Shape (all keys sorted, counts strictly positive):
//!
//! ```json
//! {
//!   "version": 1,
//!   "rules": {
//!     "D07": { "rust/src/util/json.rs": 24 }
//!   }
//! }
//! ```
//!
//! Counts may only decrease over time: the lint pass fails when a
//! (rule, file) pair exceeds its entry, notes when it has fallen below
//! (run `--update-baseline` to shrink), and `--update-baseline` refuses
//! to raise any count. Parsing is fail-closed in the house style:
//! unknown top-level keys, unknown rule ids, or malformed JSON are hard
//! errors, because a silently ignored baseline would turn the ratchet
//! off. The parser below covers exactly the subset this file needs
//! (objects, strings, unsigned integers) — hand-rolled so the xtask
//! crate stays dependency-free.

use std::collections::BTreeMap;

/// rule id → repo-relative file → allowed violation count.
pub type Baseline = BTreeMap<String, BTreeMap<String, usize>>;

pub const FORMAT_VERSION: u64 = 1;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("baseline: expected `{}` at byte {}", c as char, self.i))
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.ws();
        self.b.get(self.i).copied()
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        while self.i < self.b.len() {
            let c = self.b[self.i];
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| "baseline: dangling escape".to_string())?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        other => {
                            return Err(format!(
                                "baseline: unsupported escape `\\{}`",
                                other as char
                            ))
                        }
                    }
                }
                other => out.push(other as char),
            }
        }
        Err("baseline: unterminated string".into())
    }

    fn integer(&mut self) -> Result<u64, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len() && self.b[self.i].is_ascii_digit() {
            self.i += 1;
        }
        if self.i == start {
            return Err(format!("baseline: expected an integer at byte {start}"));
        }
        std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| "baseline: bad utf8".to_string())?
            .parse::<u64>()
            .map_err(|e| format!("baseline: integer out of range: {e}"))
    }

    /// `{ "key": <parsed by f>, ... }`
    fn object<T>(
        &mut self,
        mut f: impl FnMut(&mut Self, &str) -> Result<T, String>,
    ) -> Result<Vec<(String, T)>, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(out);
        }
        loop {
            let key = self.string()?;
            self.expect(b':')?;
            let val = f(self, &key)?;
            out.push((key, val));
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(out);
                }
                _ => return Err(format!("baseline: expected `,` or `}}` at byte {}", self.i)),
            }
        }
    }
}

/// Parse the baseline file; fail closed on anything unexpected.
pub fn parse(text: &str, known_rules: &[&str]) -> Result<Baseline, String> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    let mut version: Option<u64> = None;
    let mut rules: Option<Baseline> = None;
    let top = p.object(|p, key| match key {
        "version" => {
            version = Some(p.integer()?);
            Ok(())
        }
        "rules" => {
            let mut out: Baseline = BTreeMap::new();
            let entries = p.object(|p, rule| {
                if !known_rules.contains(&rule) {
                    return Err(format!("baseline: unknown rule id `{rule}` (fail closed)"));
                }
                let files = p.object(|p, _file| p.integer())?;
                let mut by_file = BTreeMap::new();
                for (file, count) in files {
                    if count == 0 {
                        return Err(format!(
                            "baseline: zero count for `{file}` — drop the entry instead"
                        ));
                    }
                    if by_file.insert(file.clone(), count as usize).is_some() {
                        return Err(format!("baseline: duplicate file entry `{file}`"));
                    }
                }
                Ok(by_file)
            })?;
            for (rule, by_file) in entries {
                if out.insert(rule.clone(), by_file).is_some() {
                    return Err(format!("baseline: duplicate rule entry `{rule}`"));
                }
            }
            rules = Some(out);
            Ok(())
        }
        other => Err(format!("baseline: unknown top-level key `{other}` (fail closed)")),
    });
    top?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("baseline: trailing bytes at {}", p.i));
    }
    match version {
        Some(FORMAT_VERSION) => {}
        Some(v) => return Err(format!("baseline: version {v} != {FORMAT_VERSION}")),
        None => return Err("baseline: missing `version`".into()),
    }
    rules.ok_or_else(|| "baseline: missing `rules`".into())
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// Render a baseline in the canonical sorted form [`parse`] accepts.
pub fn render(b: &Baseline) -> String {
    let mut out = String::from("{\n  \"version\": 1,\n  \"rules\": {");
    let rules: Vec<_> = b.iter().filter(|(_, files)| !files.is_empty()).collect();
    for (ri, (rule, files)) in rules.iter().enumerate() {
        out.push_str(if ri == 0 { "\n" } else { ",\n" });
        out.push_str(&format!("    \"{}\": {{", escape(rule)));
        for (fi, (file, count)) in files.iter().enumerate() {
            out.push_str(if fi == 0 { "\n" } else { ",\n" });
            out.push_str(&format!("      \"{}\": {count}", escape(file)));
        }
        out.push_str("\n    }");
    }
    if rules.is_empty() {
        out.push_str("}\n}\n");
    } else {
        out.push_str("\n  }\n}\n");
    }
    out
}
