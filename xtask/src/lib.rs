//! Repo task runner (`cargo run -p xtask -- <task>`).
//!
//! One task so far: `lint`, the determinism auditor enforcing the
//! bitwise-replay contract statically (rules D01–D07, DESIGN.md §12).
//! Dependency-free by design — same hermetic philosophy as the vendored
//! `anyhow` — so it builds in an offline container.

pub mod baseline;
pub mod lint;
pub mod rules;
pub mod scan;
