//! The determinism lint engine: walk, match, suppress, ratchet.
//!
//! Flow: walk the scan roots, mask each file with [`crate::scan`],
//! match every applicable rule from [`crate::rules`] line by line,
//! drop violations covered by a well-formed `lint:allow` pragma, then
//! compare per-(rule, file) counts against the committed ratchet
//! baseline. A count above its baseline entry is an error (per-site
//! diagnostics plus a summary when the entry is nonzero); a count below
//! it is a note inviting `--update-baseline`; malformed or unused
//! pragmas are always errors, so suppressions cannot rot in place.

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::baseline::{self, Baseline};
use crate::rules::{self, Roots, RULES};
use crate::scan;

/// Directories walked relative to the repo root (missing ones are
/// skipped so fixture trees can be partial).
pub const ROOTS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];

pub struct Options {
    /// Repo (or fixture-tree) root.
    pub root: PathBuf,
    /// Ratchet baseline path; must exist and parse (fail closed).
    pub baseline: PathBuf,
    /// Rewrite the baseline to current counts — shrink-only; any count
    /// above its entry makes the rewrite refuse.
    pub update_baseline: bool,
}

pub struct Outcome {
    /// Violations, ratchet breaches, pragma problems. Empty == pass.
    pub errors: Vec<String>,
    /// Stale-baseline notices; informational only.
    pub notes: Vec<String>,
    pub files_scanned: usize,
    /// Unsuppressed violation counts: rule → repo-relative file → n.
    pub counts: Baseline,
    pub baseline_written: bool,
}

impl Outcome {
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Recursively collect `.rs` files under `dir`, recording repo-relative
/// paths with `/` separators, children in sorted order.
fn collect(dir: &Path, rel: &str, out: &mut Vec<String>) -> Result<(), String> {
    let rd = fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut names: Vec<String> = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
        names.push(entry.file_name().to_string_lossy().into_owned());
    }
    names.sort();
    for name in names {
        let path = dir.join(&name);
        let child = format!("{rel}/{name}");
        if path.is_dir() {
            collect(&path, &child, out)?;
        } else if name.ends_with(".rs") {
            out.push(child);
        }
    }
    Ok(())
}

fn walk(root: &Path) -> Result<Vec<String>, String> {
    let mut out = Vec::new();
    for r in ROOTS {
        let dir = root.join(r);
        if dir.is_dir() {
            collect(&dir, r, &mut out)?;
        }
    }
    out.sort();
    Ok(out)
}

fn applies(rule: &rules::Rule, rel: &str) -> bool {
    if rule.exempt.contains(&rel) {
        return false;
    }
    match rule.roots {
        Roots::SrcOnly => rel.starts_with("rust/src/"),
        Roots::All => true,
    }
}

pub fn run(opts: &Options) -> Result<Outcome, String> {
    let baseline_text = fs::read_to_string(&opts.baseline).map_err(|e| {
        format!("cannot read ratchet baseline {} (fail closed): {e}", opts.baseline.display())
    })?;
    let known = rules::rule_ids();
    let allowed = baseline::parse(&baseline_text, &known)?;

    let files = walk(&opts.root)?;
    // rule → file → per-site diagnostic lines (unsuppressed).
    let mut sites: BTreeMap<String, BTreeMap<String, Vec<String>>> = BTreeMap::new();
    let mut pragma_errors: Vec<String> = Vec::new();
    for rel in &files {
        let path = opts.root.join(rel);
        let src = fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
        let sc = scan::scan(&src, &known);
        let mut used = vec![false; sc.pragmas.len()];
        for rule in RULES {
            if !applies(rule, rel) {
                continue;
            }
            for (idx, line) in sc.lines.iter().enumerate() {
                if rule.skip_cfg_test && line.in_test {
                    continue;
                }
                let msgs = rules::match_line(rule.id, &line.code);
                if msgs.is_empty() {
                    continue;
                }
                if rule.id == "D06" {
                    let justified = line.comment.contains("SAFETY:")
                        || (idx > 0 && sc.lines[idx - 1].comment.contains("SAFETY:"));
                    if justified {
                        continue;
                    }
                }
                let mut suppressed = false;
                for (pi, p) in sc.pragmas.iter().enumerate() {
                    if p.problem.is_none() && p.rule == rule.id && p.target == Some(line.number) {
                        used[pi] = true;
                        suppressed = true;
                    }
                }
                if suppressed {
                    continue;
                }
                let entry =
                    sites.entry(rule.id.to_string()).or_default().entry(rel.clone()).or_default();
                for m in msgs {
                    entry.push(format!("{rel}:{}: {m}", line.number));
                }
            }
        }
        for (pi, p) in sc.pragmas.iter().enumerate() {
            if let Some(problem) = &p.problem {
                pragma_errors.push(format!("{rel}:{}: {problem}", p.line));
            } else if !used[pi] {
                pragma_errors.push(format!(
                    "{rel}:{}: unused lint:allow({}) — no {} violation on the covered line; \
                     remove the stale pragma",
                    p.line, p.rule, p.rule
                ));
            }
        }
    }

    let mut counts: Baseline = BTreeMap::new();
    for (rule, by_file) in &sites {
        let m = counts.entry(rule.clone()).or_default();
        for (file, s) in by_file {
            m.insert(file.clone(), s.len());
        }
    }

    // Ratchet comparison over the union of observed and baselined pairs.
    let mut errors: Vec<String> = Vec::new();
    let mut notes: Vec<String> = Vec::new();
    let empty = BTreeMap::new();
    for rule in RULES {
        let id = rule.id;
        let actual_files = counts.get(id).unwrap_or(&empty);
        let allowed_files = allowed.get(id).unwrap_or(&empty);
        let mut all: Vec<&String> = actual_files.keys().chain(allowed_files.keys()).collect();
        all.sort();
        all.dedup();
        for file in all {
            let actual = actual_files.get(file).copied().unwrap_or(0);
            let allow = allowed_files.get(file).copied().unwrap_or(0);
            if actual > allow {
                if let Some(s) = sites.get(id).and_then(|m| m.get(file)) {
                    errors.extend(s.iter().cloned());
                }
                if allow > 0 {
                    errors.push(format!(
                        "{file}: {id} count {actual} exceeds the ratchet baseline ({allow}) — \
                         the ratchet only goes down"
                    ));
                }
            } else if actual < allow {
                notes.push(format!(
                    "note: {file}: {id} baseline {allow} > actual {actual} — run \
                     `cargo run -p xtask -- lint --update-baseline` to ratchet down"
                ));
            }
        }
    }
    errors.extend(pragma_errors);

    let mut baseline_written = false;
    if opts.update_baseline {
        if errors.is_empty() {
            fs::write(&opts.baseline, baseline::render(&counts)).map_err(|e| {
                format!("cannot write ratchet baseline {}: {e}", opts.baseline.display())
            })?;
            baseline_written = true;
        } else {
            errors.push(
                "refusing to rewrite the ratchet baseline while the lint pass is failing — \
                 the ratchet only goes down; fix the new violations instead"
                    .to_string(),
            );
        }
    }

    Ok(Outcome { errors, notes, files_scanned: files.len(), counts, baseline_written })
}
