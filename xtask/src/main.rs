//! `cargo run -p xtask -- lint [--root DIR] [--baseline FILE]
//! [--update-baseline]`
//!
//! Exit codes: 0 clean, 1 lint errors, 2 usage or IO/parse failure.

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::lint;

fn usage() -> ExitCode {
    eprintln!(
        "usage: cargo run -p xtask -- lint [--root DIR] [--baseline FILE] [--update-baseline]"
    );
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.first().map(String::as_str) != Some("lint") {
        return usage();
    }
    let mut root: Option<PathBuf> = None;
    let mut baseline: Option<PathBuf> = None;
    let mut update_baseline = false;
    let mut it = args[1..].iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--baseline" => match it.next() {
                Some(v) => baseline = Some(PathBuf::from(v)),
                None => return usage(),
            },
            "--update-baseline" => update_baseline = true,
            _ => return usage(),
        }
    }
    // Default to the workspace root: xtask/.. at build time.
    let root = root.unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(".."));
    let baseline = baseline.unwrap_or_else(|| root.join("xtask").join("lint-baseline.json"));
    let opts = lint::Options { root, baseline, update_baseline };
    match lint::run(&opts) {
        Ok(out) => {
            for n in &out.notes {
                println!("{n}");
            }
            for e in &out.errors {
                eprintln!("error: {e}");
            }
            if out.baseline_written {
                println!("ratchet baseline rewritten: {}", opts.baseline.display());
            }
            if out.ok() {
                println!("determinism lint: clean ({} files scanned)", out.files_scanned);
                ExitCode::SUCCESS
            } else {
                eprintln!("determinism lint: {} error(s)", out.errors.len());
                ExitCode::FAILURE
            }
        }
        Err(e) => {
            eprintln!("xtask: {e}");
            ExitCode::from(2)
        }
    }
}
