//! The determinism rule table (D01–D07) and per-line matchers.
//!
//! Every rule is a textual pattern over the masked code view from
//! [`crate::scan`]; scoping (which roots, which exempt files, whether
//! `#[cfg(test)]` scopes are skipped) lives here so the engine in
//! [`crate::lint`] stays generic. DESIGN.md §12 documents each rule's
//! rationale; the messages below are pinned verbatim by
//! `xtask/tests/lint.rs`.

/// Which scan roots a rule applies to.
#[derive(Clone, Copy, PartialEq)]
pub enum Roots {
    /// Library code only: `rust/src`.
    SrcOnly,
    /// Everything the pass walks: `rust/src`, `rust/tests`,
    /// `rust/benches`, `examples`.
    All,
}

/// One determinism rule.
pub struct Rule {
    pub id: &'static str,
    /// Skip matches inside `#[cfg(test)]` item scopes.
    pub skip_cfg_test: bool,
    pub roots: Roots,
    /// Repo-relative files where the pattern is the sanctioned home.
    pub exempt: &'static [&'static str],
}

pub const RULES: &[Rule] = &[
    // Unordered std collections: iteration order varies run to run
    // (RandomState seeding), so any observation of it breaks replay.
    // Applies to test scopes too — assertions that iterate a set are
    // exactly how the flake reaches CI.
    Rule { id: "D01", skip_cfg_test: false, roots: Roots::SrcOnly, exempt: &[] },
    // Wall-clock reads outside the one sanctioned reporting helper.
    Rule {
        id: "D02",
        skip_cfg_test: false,
        roots: Roots::All,
        exempt: &["rust/src/util/bench.rs"],
    },
    // Ambient (OS- or hasher-seeded) randomness; all draws must come
    // from counter-keyed `util::rng::Pcg64` streams.
    Rule { id: "D03", skip_cfg_test: false, roots: Roots::All, exempt: &[] },
    // Raw thread spawns outside the executor that owns the
    // parallel==serial contract.
    Rule {
        id: "D04",
        skip_cfg_test: false,
        roots: Roots::All,
        exempt: &["rust/src/coordinator/executor.rs"],
    },
    // Order-sensitive float iterator reductions outside the shared
    // kernels (util/math.rs owns reduction order; util/bench.rs reduces
    // wall-time samples, which never feed replayed state).
    Rule {
        id: "D05",
        skip_cfg_test: true,
        roots: Roots::SrcOnly,
        exempt: &["rust/src/util/math.rs", "rust/src/util/bench.rs"],
    },
    // `unsafe` without a `// SAFETY:` justification.
    Rule { id: "D06", skip_cfg_test: false, roots: Roots::All, exempt: &[] },
    // Panicking extractors on fallible paths in library code; the
    // existing mass ratchets down via xtask/lint-baseline.json.
    Rule { id: "D07", skip_cfg_test: true, roots: Roots::SrcOnly, exempt: &[] },
];

pub fn rule_ids() -> Vec<&'static str> {
    RULES.iter().map(|r| r.id).collect()
}

pub fn find(id: &str) -> Option<&'static Rule> {
    RULES.iter().find(|r| r.id == id)
}

#[inline]
fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Count occurrences of `pat` in `code` whose first and last characters
/// sit on identifier boundaries (so `Instant` never matches inside
/// `Instantiate`). Patterns may contain punctuation; only the outer
/// edges are boundary-checked.
fn count_bounded(code: &str, pat: &str) -> usize {
    let (code, pat) = (code.as_bytes(), pat.as_bytes());
    let mut n = 0usize;
    let mut i = 0usize;
    while i + pat.len() <= code.len() {
        if &code[i..i + pat.len()] == pat {
            let left_ok = i == 0 || !is_ident(code[i - 1]);
            let after = i + pat.len();
            let right_ok = after >= code.len() || !is_ident(code[after]);
            if left_ok && right_ok {
                n += 1;
                i += pat.len();
                continue;
            }
        }
        i += 1;
    }
    n
}

/// Count `spawn` call sites: the identifier preceded (modulo spaces) by
/// `.` or `::` and followed (modulo spaces) by `(`.
fn count_spawn_calls(code: &str) -> usize {
    let bytes = code.as_bytes();
    let mut n = 0usize;
    let mut i = 0usize;
    const PAT: &[u8] = b"spawn";
    while i + PAT.len() <= bytes.len() {
        if &bytes[i..i + PAT.len()] == PAT
            && (i == 0 || !is_ident(bytes[i - 1]))
            && !bytes.get(i + PAT.len()).is_some_and(|&b| is_ident(b))
        {
            let mut l = i;
            while l > 0 && bytes[l - 1] == b' ' {
                l -= 1;
            }
            let called_on = l > 0 && (bytes[l - 1] == b'.' || (l > 1 && &bytes[l - 2..l] == b"::"));
            let mut r = i + PAT.len();
            while r < bytes.len() && bytes[r] == b' ' {
                r += 1;
            }
            let invoked = r < bytes.len() && bytes[r] == b'(';
            if called_on && invoked {
                n += 1;
            }
            i += PAT.len();
            continue;
        }
        i += 1;
    }
    n
}

fn count_plain(code: &str, pat: &str) -> usize {
    code.matches(pat).count()
}

/// Match one masked code line against one rule, returning a diagnostic
/// message per hit. D06 candidates are returned unconditionally; the
/// engine drops those justified by a `// SAFETY:` comment (it alone
/// sees the neighboring lines).
pub fn match_line(id: &str, code: &str) -> Vec<String> {
    let mut out = Vec::new();
    match id {
        "D01" => {
            for name in ["HashMap", "HashSet"] {
                for _ in 0..count_bounded(code, name) {
                    out.push(format!(
                        "D01 unordered collection `{name}` — iteration order is \
                         nondeterministic and breaks bitwise replay; use BTreeMap/BTreeSet \
                         or a sorted Vec"
                    ));
                }
            }
        }
        "D02" => {
            for pat in ["Instant::now", "SystemTime::now", "UNIX_EPOCH"] {
                for _ in 0..count_bounded(code, pat) {
                    out.push(format!(
                        "D02 wall-clock read `{pat}` outside util/bench — wall time must \
                         never reach replayed state; use util::bench::WallTimer for reporting"
                    ));
                }
            }
        }
        "D03" => {
            for pat in [
                "thread_rng",
                "from_entropy",
                "OsRng",
                "StdRng",
                "SmallRng",
                "getrandom",
                "RandomState",
                "DefaultHasher",
            ] {
                for _ in 0..count_bounded(code, pat) {
                    out.push(format!(
                        "D03 ambient randomness `{pat}` — every random draw must come \
                         from a counter-keyed util::rng::Pcg64 stream"
                    ));
                }
            }
        }
        "D04" => {
            for _ in 0..count_spawn_calls(code) {
                out.push(
                    "D04 raw thread spawn outside coordinator::executor — unmanaged \
                     threads break the parallel==serial contract"
                        .to_string(),
                );
            }
        }
        "D05" => {
            let pats = [".sum::<f32>(", ".sum::<f64>(", ".product::<f32>(", ".product::<f64>("];
            for pat in pats {
                for _ in 0..count_plain(code, pat) {
                    let name = &pat[..pat.len() - 1];
                    out.push(format!(
                        "D05 order-sensitive float reduction `{name}()` — reduction order \
                         must have one home; route through util::math \
                         (sum_f64/mean_f64/norm2_f64)"
                    ));
                }
            }
        }
        "D06" => {
            for _ in 0..count_bounded(code, "unsafe") {
                out.push(
                    "D06 `unsafe` without a `// SAFETY:` comment on the same or \
                     preceding line"
                        .to_string(),
                );
            }
        }
        "D07" => {
            for (pat, name) in [(".unwrap()", ".unwrap()"), (".expect(", ".expect(..)")] {
                for _ in 0..count_plain(code, pat) {
                    out.push(format!(
                        "D07 `{name}` on a fallible path in library code — return a \
                         Result instead (existing sites ratchet down via \
                         xtask/lint-baseline.json)"
                    ));
                }
            }
        }
        other => panic!("unknown rule {other}"),
    }
    out
}
