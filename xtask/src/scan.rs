//! Hand-rolled Rust source scanner for the determinism lint pass.
//!
//! Not a parser: a byte-level state machine that produces a *code view*
//! of a source file — comment and literal contents blanked out with
//! spaces so line structure survives — plus per-line comment text,
//! `#[cfg(test)]` item-scope tracking by brace depth, and parsed
//! `// lint:allow(D0x): <reason>` pragmas. Rule matching then works on
//! the masked code with plain substring + identifier-boundary checks.
//! Same hermetic philosophy as the vendored `anyhow`: no syn, no
//! proc-macro machinery, nothing an offline container can't build.
//!
//! Handled literal forms: line comments, nested block comments, string
//! literals (with `\"` escapes and `\`-newline continuations), byte
//! strings, raw strings `r"…"`/`r#"…"#` (and `br` variants, any hash
//! depth), char and byte-char literals including escapes, and the
//! char-literal/lifetime ambiguity (`'a'` vs `<'a>`).
//!
//! Known, documented limits (see DESIGN.md §12): `#[cfg(test)]` is only
//! recognized on its own line (the rustfmt-enforced house style), and
//! macro-generated code is scanned as written, not as expanded.

/// One source line of the masked code view.
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code with comment/literal contents replaced by spaces.
    pub code: String,
    /// Concatenated text of every comment fragment on this line.
    pub comment: String,
    /// True inside a `#[cfg(test)]` item's brace block (including the
    /// opening and closing lines).
    pub in_test: bool,
}

/// One `lint:allow(...)` pragma found in a comment.
pub struct Pragma {
    /// Line the pragma is written on (1-based).
    pub line: usize,
    /// The rule id named inside the parentheses (may be unknown).
    pub rule: String,
    /// The code line this pragma covers: its own line when the pragma
    /// trails code, otherwise the next line that contains code.
    pub target: Option<usize>,
    /// Why the pragma cannot suppress anything (malformed / unknown
    /// rule / missing reason); `None` for a well-formed pragma.
    pub problem: Option<String>,
}

/// Full scan result for one file.
pub struct Scan {
    pub lines: Vec<Line>,
    pub pragmas: Vec<Pragma>,
}

#[inline]
fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Byte length of the UTF-8 character starting at `b` (1 for malformed
/// continuation bytes — good enough for literal-vs-lifetime sniffing).
#[inline]
fn utf8_len(b: u8) -> usize {
    if b < 0x80 {
        1
    } else if b < 0xE0 {
        2
    } else if b < 0xF0 {
        3
    } else {
        4
    }
}

enum State {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
}

/// Detect a raw-string opener (`r"`, `r#"`, `br##"` …) at `i`. Returns
/// `(hash_count, prefix_len)` with `prefix_len` covering everything up
/// to and including the opening quote.
fn raw_str_open(src: &[u8], i: usize) -> Option<(u32, usize)> {
    if i > 0 && (is_ident(src[i - 1]) || src[i - 1] == b'"') {
        return None;
    }
    let mut j = i;
    if src.get(j) == Some(&b'b') {
        j += 1;
    }
    if src.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while src.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if src.get(j) == Some(&b'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Mask a source file: per-line code view + per-line comment text.
fn mask(src: &[u8]) -> (Vec<String>, Vec<String>) {
    let mut code: Vec<Vec<u8>> = vec![Vec::new()];
    let mut comment: Vec<Vec<u8>> = vec![Vec::new()];
    let mut state = State::Code;
    let mut i = 0usize;
    while i < src.len() {
        let b = src[i];
        if b == b'\n' {
            if matches!(state, State::LineComment) {
                state = State::Code;
            }
            code.push(Vec::new());
            comment.push(Vec::new());
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                if b == b'/' && src.get(i + 1) == Some(&b'/') {
                    state = State::LineComment;
                    i += 2;
                } else if b == b'/' && src.get(i + 1) == Some(&b'*') {
                    state = State::BlockComment(1);
                    i += 2;
                } else if let Some((hashes, len)) = raw_str_open(src, i) {
                    state = State::RawStr(hashes);
                    push_spaces(&mut code, len);
                    i += len;
                } else if b == b'"' {
                    state = State::Str;
                    push_spaces(&mut code, 1);
                    i += 1;
                } else if b == b'\'' {
                    // Char literal vs lifetime. A literal is exactly one
                    // (possibly escaped) character between quotes;
                    // anything else (`'a`, `'static`, `'_`) is a
                    // lifetime and only the quote itself is consumed.
                    if src.get(i + 1) == Some(&b'\\') {
                        let mut j = i + 3; // skip the escaped byte
                        while j < src.len() && src[j] != b'\'' && src[j] != b'\n' {
                            j += 1;
                        }
                        let end = if j < src.len() && src[j] == b'\'' { j + 1 } else { j };
                        push_spaces(&mut code, end - i);
                        i = end;
                    } else {
                        let clen = src.get(i + 1).map(|&c| utf8_len(c)).unwrap_or(1);
                        if src.get(i + 1 + clen) == Some(&b'\'') {
                            push_spaces(&mut code, clen + 2);
                            i += clen + 2;
                        } else {
                            push_spaces(&mut code, 1);
                            i += 1;
                        }
                    }
                } else {
                    code.last_mut().expect("line buffer").push(b);
                    i += 1;
                }
            }
            State::LineComment => {
                comment.last_mut().expect("line buffer").push(b);
                i += 1;
            }
            State::BlockComment(depth) => {
                if b == b'/' && src.get(i + 1) == Some(&b'*') {
                    state = State::BlockComment(depth + 1);
                    i += 2;
                } else if b == b'*' && src.get(i + 1) == Some(&b'/') {
                    state = if depth == 1 { State::Code } else { State::BlockComment(depth - 1) };
                    i += 2;
                } else {
                    comment.last_mut().expect("line buffer").push(b);
                    i += 1;
                }
            }
            State::Str => {
                if b == b'\\' {
                    if src.get(i + 1) == Some(&b'\n') {
                        i += 1; // leave the newline to the top handler
                    } else {
                        push_spaces(&mut code, 2);
                        i += 2;
                    }
                } else if b == b'"' {
                    state = State::Code;
                    push_spaces(&mut code, 1);
                    i += 1;
                } else {
                    push_spaces(&mut code, 1);
                    i += 1;
                }
            }
            State::RawStr(hashes) => {
                if b == b'"' {
                    let h = hashes as usize;
                    let closed = (1..=h).all(|k| src.get(i + k) == Some(&b'#'));
                    if closed {
                        state = State::Code;
                        push_spaces(&mut code, 1 + h);
                        i += 1 + h;
                        continue;
                    }
                }
                push_spaces(&mut code, 1);
                i += 1;
            }
        }
    }
    let to_string = |v: Vec<Vec<u8>>| {
        v.into_iter().map(|l| String::from_utf8_lossy(&l).into_owned()).collect()
    };
    (to_string(code), to_string(comment))
}

fn push_spaces(code: &mut [Vec<u8>], n: usize) {
    let last = code.last_mut().expect("line buffer");
    for _ in 0..n {
        last.push(b' ');
    }
}

/// Track `#[cfg(test)]` item scopes over the masked code lines: the
/// attribute on its own line arms a latch; the next `{` opens the test
/// block (a `;` first — attribute on a braceless item — disarms it),
/// and the block closes when brace depth returns to its opening level.
fn mark_test_scopes(code: &[String]) -> Vec<bool> {
    let mut out = vec![false; code.len()];
    let mut depth = 0usize;
    let mut awaiting = false;
    let mut test_open: Option<usize> = None;
    for (idx, line) in code.iter().enumerate() {
        let started_in_test = test_open.is_some();
        let mut activated = false;
        if line.trim() == "#[cfg(test)]" {
            awaiting = test_open.is_none();
        } else {
            for b in line.bytes() {
                match b {
                    b'{' => {
                        if awaiting {
                            test_open = Some(depth);
                            awaiting = false;
                            activated = true;
                        }
                        depth += 1;
                    }
                    b'}' => {
                        depth = depth.saturating_sub(1);
                        if test_open.is_some_and(|open| depth <= open) {
                            test_open = None;
                        }
                    }
                    b';' => {
                        if awaiting && test_open.is_none() {
                            awaiting = false;
                        }
                    }
                    _ => {}
                }
            }
        }
        out[idx] = started_in_test || activated;
    }
    out
}

/// Extract every `lint:allow(...)` pragma from one line's comment text.
fn parse_pragmas(line_no: usize, comment: &str, known: &[&str], out: &mut Vec<Pragma>) {
    const NEEDLE: &str = "lint:allow(";
    let mut at = 0usize;
    while let Some(pos) = comment[at..].find(NEEDLE) {
        let rest = &comment[at + pos + NEEDLE.len()..];
        at += pos + NEEDLE.len();
        let Some(close) = rest.find(')') else {
            out.push(Pragma {
                line: line_no,
                rule: String::new(),
                target: None,
                problem: Some("lint:allow( without a closing parenthesis".into()),
            });
            continue;
        };
        let rule = rest[..close].trim().to_string();
        let after = &rest[close + 1..];
        let problem = if !known.contains(&rule.as_str()) {
            Some(format!("lint:allow({rule}) names an unknown rule (known: D01..D07)"))
        } else if !after.starts_with(':') || after[1..].trim().is_empty() {
            Some(format!(
                "lint:allow({rule}) is missing its mandatory reason — \
                 write `// lint:allow({rule}): <why this is sound>`"
            ))
        } else {
            None
        };
        out.push(Pragma { line: line_no, rule, target: None, problem });
    }
}

/// Scan one source file into its code view, test-scope map and pragmas.
pub fn scan(source: &str, known_rules: &[&str]) -> Scan {
    let (code, comment) = mask(source.as_bytes());
    let in_test = mark_test_scopes(&code);
    let mut pragmas: Vec<Pragma> = Vec::new();
    let mut pending: Vec<usize> = Vec::new(); // indices awaiting a target
    let mut lines = Vec::with_capacity(code.len());
    for (idx, (code, comment)) in code.into_iter().zip(comment).enumerate() {
        let number = idx + 1;
        let before = pragmas.len();
        parse_pragmas(number, &comment, known_rules, &mut pragmas);
        let has_code = !code.trim().is_empty();
        if has_code {
            // Standalone pragmas above this line cover it; pragmas
            // written on a code line cover that same line.
            for p in pending.drain(..) {
                pragmas[p].target = Some(number);
            }
            for p in pragmas.iter_mut().skip(before) {
                p.target = Some(number);
            }
        } else {
            pending.extend(before..pragmas.len());
        }
        lines.push(Line { number, code, comment, in_test: in_test[idx] });
    }
    // Pragmas at EOF with no code after them cover nothing and will be
    // reported as unused.
    Scan { lines, pragmas }
}
