use std::collections::HashMap;

pub fn fresh() -> HashMap<u32, u32> {
    HashMap::new()
}
