pub fn now_s() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}
