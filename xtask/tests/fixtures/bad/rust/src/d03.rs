pub fn ambient_seed() -> u64 {
    let h = std::collections::hash_map::DefaultHasher::new();
    std::hash::Hasher::finish(&h)
}
