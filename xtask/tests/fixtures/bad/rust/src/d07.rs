pub fn parse_port(s: &str) -> u32 {
    s.parse().unwrap()
}
