pub fn helper() {
    // lint:allow(D04): fixture stands in for a sanctioned helper thread
    std::thread::spawn(|| {});
}

pub fn timed() -> f64 {
    let t = std::time::Instant::now(); // lint:allow(D02): report-only timing in a fixture
    t.elapsed().as_secs_f64()
}
