pub fn run_managed(f: impl FnOnce() + Send + 'static) {
    std::thread::spawn(f);
}
