//! Mentions HashMap, Instant::now and unsafe in doc comments only.

pub const DOC: &str = "HashMap Instant::now thread_rng .sum::<f64>( unsafe .unwrap() spawn(";

pub const RAW: &str = r#"HashMap "quoted" unsafe .unwrap()"#;

/* block comment: HashMap /* nested: SystemTime::now */ .unwrap() */
pub fn lifetimes<'a>(x: &'a str, _y: &'a str) -> &'a str {
    let marker = 'H';
    let escaped = '\'';
    let _ = (marker, escaped);
    x
}
