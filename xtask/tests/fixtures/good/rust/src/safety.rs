pub fn deref(p: *const u8) -> u8 {
    // SAFETY: caller guarantees p is valid for reads
    unsafe { *p }
}

pub fn deref_trailing(p: *const u8) -> u8 {
    unsafe { *p } // SAFETY: caller guarantees p is valid for reads
}
