pub fn double(x: u32) -> u32 {
    x * 2
}

#[cfg(test)]
mod tests {
    #[test]
    fn reductions_and_unwraps_are_fine_in_tests() {
        let xs = [1.0f64, 2.0];
        let s = xs.iter().sum::<f64>();
        assert!(s > 2.9);
        let v: Option<u32> = Some(1);
        v.unwrap();
    }
}
