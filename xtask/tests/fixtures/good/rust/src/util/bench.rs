pub fn sample() -> std::time::Instant {
    std::time::Instant::now()
}

pub fn mean_wall(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}
