pub fn sum_f64(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>()
}
