#[test]
fn reductions_and_unwraps_allowed_under_tests_root() {
    let xs = [1.0f64];
    assert!(xs.iter().sum::<f64>() > 0.0);
    "1".parse::<u32>().unwrap();
}
