pub fn stale() -> u32 {
    // lint:allow(D04): nothing on the next line actually spawns
    let x = 1;
    x
}

pub fn missing_reason() {
    // lint:allow(D04)
    std::thread::spawn(|| {});
}

pub fn unknown_rule() {
    // lint:allow(D99): not a rule at all
    std::thread::spawn(|| {});
}
