//! Fixture-driven tests for the determinism lint pass: exact diagnostic
//! strings (rule id, file, line), known-good files, pragma/unused-allow
//! semantics, ratchet behavior, and the scanner's literal handling.

use std::collections::BTreeMap;
use std::path::PathBuf;

use xtask::lint::{run, Options, Outcome};
use xtask::rules::rule_ids;
use xtask::scan::scan;

fn fixture(path: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join(path)
}

fn lint_fixture(root: &str) -> Outcome {
    run(&Options {
        root: fixture(root),
        baseline: fixture("empty-baseline.json"),
        update_baseline: false,
    })
    .unwrap()
}

fn assert_has(out: &Outcome, expected: &str) {
    assert!(
        out.errors.iter().any(|e| e == expected),
        "missing diagnostic:\n  want: {expected}\n  got:\n{}",
        out.errors.join("\n")
    );
}

// ---------------------------------------------------------------- bad corpus

#[test]
fn bad_corpus_pins_exact_diagnostics() {
    let out = lint_fixture("bad");
    let d01 = "D01 unordered collection `HashMap` — iteration order is nondeterministic and \
               breaks bitwise replay; use BTreeMap/BTreeSet or a sorted Vec";
    assert_has(&out, &format!("rust/src/d01.rs:1: {d01}"));
    assert_has(&out, &format!("rust/src/d01.rs:3: {d01}"));
    assert_has(&out, &format!("rust/src/d01.rs:4: {d01}"));
    assert_has(
        &out,
        "rust/src/d02.rs:2: D02 wall-clock read `Instant::now` outside util/bench — wall time \
         must never reach replayed state; use util::bench::WallTimer for reporting",
    );
    assert_has(
        &out,
        "rust/src/d03.rs:2: D03 ambient randomness `DefaultHasher` — every random draw must \
         come from a counter-keyed util::rng::Pcg64 stream",
    );
    assert_has(
        &out,
        "rust/src/d04.rs:2: D04 raw thread spawn outside coordinator::executor — unmanaged \
         threads break the parallel==serial contract",
    );
    assert_has(
        &out,
        "rust/src/d05.rs:2: D05 order-sensitive float reduction `.sum::<f64>()` — reduction \
         order must have one home; route through util::math (sum_f64/mean_f64/norm2_f64)",
    );
    assert_has(
        &out,
        "rust/src/d06.rs:2: D06 `unsafe` without a `// SAFETY:` comment on the same or \
         preceding line",
    );
    assert_has(
        &out,
        "rust/src/d07.rs:2: D07 `.unwrap()` on a fallible path in library code — return a \
         Result instead (existing sites ratchet down via xtask/lint-baseline.json)",
    );
    assert_eq!(out.errors.len(), 9, "unexpected extras:\n{}", out.errors.join("\n"));
    assert_eq!(out.counts["D01"]["rust/src/d01.rs"], 3);
    assert!(out.notes.is_empty());
}

// ---------------------------------------------------------------- good corpus

#[test]
fn good_corpus_is_clean() {
    let out = lint_fixture("good");
    assert!(out.ok(), "good corpus must pass:\n{}", out.errors.join("\n"));
    assert!(out.notes.is_empty(), "{:?}", out.notes);
    assert_eq!(out.files_scanned, 8);
    assert!(out.counts.is_empty(), "{:?}", out.counts);
}

// ------------------------------------------------------------ pragma semantics

#[test]
fn pragma_semantics_are_enforced() {
    let out = lint_fixture("pragmas");
    assert_has(
        &out,
        "rust/src/pragmas.rs:2: unused lint:allow(D04) — no D04 violation on the covered \
         line; remove the stale pragma",
    );
    assert_has(
        &out,
        "rust/src/pragmas.rs:8: lint:allow(D04) is missing its mandatory reason — write \
         `// lint:allow(D04): <why this is sound>`",
    );
    assert_has(
        &out,
        "rust/src/pragmas.rs:13: lint:allow(D99) names an unknown rule (known: D01..D07)",
    );
    let d04 = "D04 raw thread spawn outside coordinator::executor — unmanaged threads break \
               the parallel==serial contract";
    assert_has(&out, &format!("rust/src/pragmas.rs:9: {d04}"));
    assert_has(&out, &format!("rust/src/pragmas.rs:14: {d04}"));
    assert_eq!(out.errors.len(), 5, "{}", out.errors.join("\n"));
}

// ---------------------------------------------------------------- the ratchet

const APP: &str = "pub fn f(a: Option<u32>, b: Option<u32>) -> u32 {\n    \
                   a.unwrap() + b.unwrap()\n}\n";

fn tmp_tree(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("xtask_lint_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("rust").join("src")).unwrap();
    dir
}

fn put(dir: &std::path::Path, rel: &str, text: &str) {
    let p = dir.join(rel);
    std::fs::create_dir_all(p.parent().unwrap()).unwrap();
    std::fs::write(p, text).unwrap();
}

fn d07_baseline(file: &str, n: usize) -> String {
    let mut by_file = BTreeMap::new();
    by_file.insert(file.to_string(), n);
    let mut b = BTreeMap::new();
    b.insert("D07".to_string(), by_file);
    xtask::baseline::render(&b)
}

fn opts(dir: &std::path::Path, update: bool) -> Options {
    Options {
        root: dir.to_path_buf(),
        baseline: dir.join("baseline.json"),
        update_baseline: update,
    }
}

#[test]
fn ratchet_at_par_passes_silently() {
    let dir = tmp_tree("at_par");
    put(&dir, "rust/src/app.rs", APP);
    put(&dir, "baseline.json", &d07_baseline("rust/src/app.rs", 2));
    let out = run(&opts(&dir, false)).unwrap();
    assert!(out.ok(), "{}", out.errors.join("\n"));
    assert!(out.notes.is_empty(), "{:?}", out.notes);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ratchet_exceeded_fails_with_sites_and_summary() {
    let dir = tmp_tree("exceeded");
    put(&dir, "rust/src/app.rs", APP);
    put(&dir, "baseline.json", &d07_baseline("rust/src/app.rs", 1));
    let out = run(&opts(&dir, false)).unwrap();
    assert_has(
        &out,
        "rust/src/app.rs: D07 count 2 exceeds the ratchet baseline (1) — the ratchet only \
         goes down",
    );
    // Both sites are reported so the offender is findable either way.
    let d07 = "D07 `.unwrap()` on a fallible path in library code — return a Result instead \
               (existing sites ratchet down via xtask/lint-baseline.json)";
    assert_has(&out, &format!("rust/src/app.rs:2: {d07}"));
    assert_eq!(out.errors.len(), 3, "{}", out.errors.join("\n"));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn ratchet_below_baseline_notes_the_slack() {
    let dir = tmp_tree("stale");
    put(&dir, "rust/src/app.rs", APP);
    put(&dir, "baseline.json", &d07_baseline("rust/src/app.rs", 3));
    let out = run(&opts(&dir, false)).unwrap();
    assert!(out.ok(), "{}", out.errors.join("\n"));
    assert_eq!(
        out.notes,
        vec![
            "note: rust/src/app.rs: D07 baseline 3 > actual 2 — run \
             `cargo run -p xtask -- lint --update-baseline` to ratchet down"
                .to_string()
        ]
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn update_baseline_shrinks_and_only_shrinks() {
    let dir = tmp_tree("update");
    put(&dir, "rust/src/app.rs", APP);
    put(&dir, "baseline.json", &d07_baseline("rust/src/app.rs", 3));
    let out = run(&opts(&dir, true)).unwrap();
    assert!(out.ok() && out.baseline_written);
    let rewritten = std::fs::read_to_string(dir.join("baseline.json")).unwrap();
    let parsed = xtask::baseline::parse(&rewritten, &rule_ids()).unwrap();
    assert_eq!(parsed["D07"]["rust/src/app.rs"], 2);
    // The rewritten baseline is exactly at par: a second pass is silent.
    let again = run(&opts(&dir, false)).unwrap();
    assert!(again.ok() && again.notes.is_empty());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn update_baseline_refuses_to_raise_the_ratchet() {
    let dir = tmp_tree("refuse");
    put(&dir, "rust/src/app.rs", APP);
    let before = d07_baseline("rust/src/app.rs", 1);
    put(&dir, "baseline.json", &before);
    let out = run(&opts(&dir, true)).unwrap();
    assert!(!out.ok() && !out.baseline_written);
    assert_has(
        &out,
        "refusing to rewrite the ratchet baseline while the lint pass is failing — the \
         ratchet only goes down; fix the new violations instead",
    );
    assert_eq!(std::fs::read_to_string(dir.join("baseline.json")).unwrap(), before);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn baseline_is_fail_closed() {
    let dir = tmp_tree("fail_closed");
    put(&dir, "rust/src/app.rs", "pub fn ok() {}\n");
    // Missing baseline file.
    let e = run(&opts(&dir, false)).unwrap_err();
    assert!(e.contains("fail closed"), "{e}");
    // Unknown rule id.
    put(&dir, "baseline.json", "{\"version\": 1, \"rules\": {\"D42\": {\"a.rs\": 1}}}");
    let e = run(&opts(&dir, false)).unwrap_err();
    assert!(e.contains("unknown rule id"), "{e}");
    // Wrong format version.
    put(&dir, "baseline.json", "{\"version\": 2, \"rules\": {}}");
    let e = run(&opts(&dir, false)).unwrap_err();
    assert!(e.contains("version 2 != 1"), "{e}");
    // Unknown top-level key.
    put(&dir, "baseline.json", "{\"version\": 1, \"rules\": {}, \"extra\": {}}");
    let e = run(&opts(&dir, false)).unwrap_err();
    assert!(e.contains("unknown top-level key"), "{e}");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn introducing_a_synthetic_violation_flips_a_clean_tree() {
    let dir = tmp_tree("flip");
    put(&dir, "rust/src/clean.rs", "pub fn ok(x: u32) -> u32 {\n    x + 1\n}\n");
    put(&dir, "baseline.json", &xtask::baseline::render(&BTreeMap::new()));
    assert!(run(&opts(&dir, false)).unwrap().ok());
    let snippets = [
        "use std::collections::HashSet;\n",
        "pub fn t() -> std::time::SystemTime {\n    std::time::SystemTime::now()\n}\n",
        "pub fn r() {\n    let _ = rand::thread_rng();\n}\n",
        "pub fn s() {\n    std::thread::spawn(|| {});\n}\n",
        "pub fn p(xs: &[f32]) -> f32 {\n    xs.iter().product::<f32>()\n}\n",
        "pub fn u(p: *const u8) -> u8 {\n    unsafe { *p }\n}\n",
        "pub fn w(s: &str) -> u32 {\n    s.parse().expect(\"fixture\")\n}\n",
    ];
    for (i, snippet) in snippets.iter().enumerate() {
        put(&dir, "rust/src/synthetic.rs", snippet);
        let out = run(&opts(&dir, false)).unwrap();
        assert_eq!(out.errors.len(), 1, "snippet {i}:\n{}", out.errors.join("\n"));
        let want = format!("D0{}", i + 1);
        assert!(out.errors[0].contains(&want), "snippet {i}: {}", out.errors[0]);
        std::fs::remove_file(dir.join("rust/src/synthetic.rs")).unwrap();
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

// ------------------------------------------------------------- the real tree

#[test]
fn real_tree_is_clean_under_the_committed_baseline() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..");
    let out = run(&Options {
        root: root.clone(),
        baseline: root.join("xtask").join("lint-baseline.json"),
        update_baseline: false,
    })
    .unwrap();
    assert!(
        out.errors.is_empty(),
        "determinism lint must pass on the tree:\n{}",
        out.errors.join("\n")
    );
    // D01–D06 roll out at zero: only D07 may carry ratcheted debt.
    for id in ["D01", "D02", "D03", "D04", "D05", "D06"] {
        assert!(out.counts.get(id).is_none(), "{id} must be at zero: {:?}", out.counts.get(id));
    }
}

// ------------------------------------------------------------ scanner details

fn code_lines(src: &str) -> Vec<String> {
    scan(src, &rule_ids()).lines.into_iter().map(|l| l.code).collect()
}

#[test]
fn masking_blanks_strings_and_comments() {
    let src = "let a = \"HashMap\"; // unsafe HashMap\nlet b = 1; /* .unwrap() */\n";
    for (i, code) in code_lines(src).iter().enumerate() {
        for pat in ["HashMap", "unsafe", ".unwrap()"] {
            assert!(!code.contains(pat), "line {i}: {code:?}");
        }
    }
}

#[test]
fn masking_handles_raw_strings_and_nested_block_comments() {
    let lines = code_lines("let r = r#\"Instant::now \"x\" HashSet\"#;\nlet n = 2;\n");
    assert!(!lines[0].contains("Instant") && !lines[0].contains("HashSet"), "{:?}", lines[0]);
    assert!(lines[1].contains("let n = 2;"));
    let lines = code_lines("/* a /* nested SystemTime::now */ b */ let x = 1;\n");
    assert_eq!(lines[0].trim(), "let x = 1;");
}

#[test]
fn masking_distinguishes_char_literals_from_lifetimes() {
    let lines = code_lines("fn f<'a>(x: &'a str) -> &'a str {\n    let c = 'H';\n    x\n}\n");
    assert!(lines[0].contains("<'a>") && lines[0].contains("&'a str"), "{:?}", lines[0]);
    assert!(!lines[1].contains('H'), "{:?}", lines[1]);
    assert!(lines[1].contains("let c ="), "{:?}", lines[1]);
}

#[test]
fn masking_follows_string_continuations_across_lines() {
    let lines = code_lines("let s = \"a\\\n   HashMap more\";\nlet t = 3;\n");
    assert!(!lines[1].contains("HashMap"), "{:?}", lines[1]);
    assert!(lines[2].contains("let t = 3;"), "{:?}", lines[2]);
}

#[test]
fn cfg_test_scopes_are_tracked_by_brace_depth() {
    let src = "pub fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\npub fn c() {}\n";
    let flags: Vec<bool> = scan(src, &rule_ids()).lines.iter().map(|l| l.in_test).collect();
    assert_eq!(flags, vec![false, false, true, true, true, false]);
    // The attribute on a braceless item arms nothing once `;` lands.
    let src = "#[cfg(test)]\nuse foo::bar;\nmod real {\n    fn d() {}\n}\n";
    let flags: Vec<bool> = scan(src, &rule_ids()).lines.iter().map(|l| l.in_test).collect();
    assert_eq!(flags, vec![false, false, false, false, false]);
}

#[test]
fn standalone_pragmas_attach_to_the_next_code_line() {
    let src = "// lint:allow(D07): covers the line after the gap\n\nlet v = x.unwrap();\n";
    let sc = scan(src, &rule_ids());
    assert_eq!(sc.pragmas.len(), 1);
    assert_eq!(sc.pragmas[0].target, Some(3));
    assert!(sc.pragmas[0].problem.is_none());
}
