#!/usr/bin/env python3
"""Bootstrap/refresh xtask/lint-baseline.json without a Rust toolchain.

A line-for-line mirror of the xtask scanner (xtask/src/scan.rs) and rule
table (xtask/src/rules.rs): masks comments/strings/chars, tracks
#[cfg(test)] scopes by brace depth, honors `// lint:allow(D0x): reason`
pragmas, and counts per-(rule, file) violations. D01–D06 must come out
at zero (the script fails and lists them otherwise); D07's remaining
mass becomes the ratchet baseline.

The real linter treats baseline entries above the actual count as
passing notes, so a mirror overcount is harmless; an undercount fails
the driver's `cargo run -p xtask -- lint` — which is exactly the bug
report we'd want.

Usage: python3 xtask/tools/gen_baseline.py [repo_root]
"""

import sys
from pathlib import Path

ROOTS = ["rust/src", "rust/tests", "rust/benches", "examples"]

RULES = {
    "D01": dict(skip_test=False, src_only=True, exempt=[]),
    "D02": dict(skip_test=False, src_only=False, exempt=["rust/src/util/bench.rs"]),
    "D03": dict(skip_test=False, src_only=False, exempt=[]),
    "D04": dict(skip_test=False, src_only=False, exempt=["rust/src/coordinator/executor.rs"]),
    "D05": dict(
        skip_test=True,
        src_only=True,
        exempt=["rust/src/util/math.rs", "rust/src/util/bench.rs"],
    ),
    "D06": dict(skip_test=False, src_only=False, exempt=[]),
    "D07": dict(skip_test=True, src_only=True, exempt=[]),
}


def is_ident(b):
    return (48 <= b <= 57) or (65 <= b <= 90) or (97 <= b <= 122) or b == 95


def utf8_len(b):
    if b < 0x80:
        return 1
    if b < 0xE0:
        return 2
    if b < 0xF0:
        return 3
    return 4


def raw_str_open(src, i):
    if i > 0 and (is_ident(src[i - 1]) or src[i - 1] == 0x22):
        return None
    j = i
    if j < len(src) and src[j] == ord("b"):
        j += 1
    if j >= len(src) or src[j] != ord("r"):
        return None
    j += 1
    hashes = 0
    while j < len(src) and src[j] == ord("#"):
        hashes += 1
        j += 1
    if j < len(src) and src[j] == 0x22:
        return (hashes, j + 1 - i)
    return None


CODE, LINE_COMMENT, BLOCK_COMMENT, STR, RAW_STR = range(5)


def mask(src):
    code, comment = [bytearray()], [bytearray()]
    state, depth, hashes = CODE, 0, 0
    i = 0
    n = len(src)
    while i < n:
        b = src[i]
        if b == 0x0A:  # \n
            if state == LINE_COMMENT:
                state = CODE
            code.append(bytearray())
            comment.append(bytearray())
            i += 1
            continue
        if state == CODE:
            if b == ord("/") and i + 1 < n and src[i + 1] == ord("/"):
                state = LINE_COMMENT
                i += 2
            elif b == ord("/") and i + 1 < n and src[i + 1] == ord("*"):
                state, depth = BLOCK_COMMENT, 1
                i += 2
            elif (ro := raw_str_open(src, i)) is not None:
                hashes = ro[0]
                state = RAW_STR
                code[-1] += b" " * ro[1]
                i += ro[1]
            elif b == 0x22:  # "
                state = STR
                code[-1] += b" "
                i += 1
            elif b == ord("'"):
                if i + 1 < n and src[i + 1] == ord("\\"):
                    j = i + 3
                    while j < n and src[j] != ord("'") and src[j] != 0x0A:
                        j += 1
                    end = j + 1 if j < n and src[j] == ord("'") else j
                    code[-1] += b" " * (end - i)
                    i = end
                else:
                    clen = utf8_len(src[i + 1]) if i + 1 < n else 1
                    if i + 1 + clen < n and src[i + 1 + clen] == ord("'"):
                        code[-1] += b" " * (clen + 2)
                        i += clen + 2
                    else:
                        code[-1] += b" "
                        i += 1
            else:
                code[-1].append(b)
                i += 1
        elif state == LINE_COMMENT:
            comment[-1].append(b)
            i += 1
        elif state == BLOCK_COMMENT:
            if b == ord("/") and i + 1 < n and src[i + 1] == ord("*"):
                depth += 1
                i += 2
            elif b == ord("*") and i + 1 < n and src[i + 1] == ord("/"):
                depth -= 1
                if depth == 0:
                    state = CODE
                i += 2
            else:
                comment[-1].append(b)
                i += 1
        elif state == STR:
            if b == ord("\\"):
                if i + 1 < n and src[i + 1] == 0x0A:
                    i += 1  # leave the newline to the top handler
                else:
                    code[-1] += b"  "
                    i += 2
            elif b == 0x22:
                state = CODE
                code[-1] += b" "
                i += 1
            else:
                code[-1] += b" "
                i += 1
        else:  # RAW_STR
            if b == 0x22 and all(
                i + k < n and src[i + k] == ord("#") for k in range(1, hashes + 1)
            ):
                state = CODE
                code[-1] += b" " * (1 + hashes)
                i += 1 + hashes
            else:
                code[-1] += b" "
                i += 1
    dec = lambda v: [bytes(l).decode("utf-8", "replace") for l in v]
    return dec(code), dec(comment)


def mark_test_scopes(code):
    out = [False] * len(code)
    depth = 0
    awaiting = False
    test_open = None
    for idx, line in enumerate(code):
        started = test_open is not None
        activated = False
        if line.strip() == "#[cfg(test)]":
            awaiting = test_open is None
        else:
            for ch in line:
                if ch == "{":
                    if awaiting:
                        test_open = depth
                        awaiting = False
                        activated = True
                    depth += 1
                elif ch == "}":
                    depth = max(0, depth - 1)
                    if test_open is not None and depth <= test_open:
                        test_open = None
                elif ch == ";":
                    if awaiting and test_open is None:
                        awaiting = False
        out[idx] = started or activated
    return out


def parse_pragmas(comment_lines, code_lines):
    """[(line, rule, target, well_formed)] mirroring scan.rs semantics."""
    pragmas = []
    pending = []
    for idx, comment in enumerate(comment_lines):
        number = idx + 1
        before = len(pragmas)
        at = 0
        while (pos := comment.find("lint:allow(", at)) != -1:
            rest = comment[pos + len("lint:allow(") :]
            at = pos + len("lint:allow(")
            close = rest.find(")")
            if close == -1:
                pragmas.append([number, "", None, False])
                continue
            rule = rest[:close].strip()
            after = rest[close + 1 :]
            ok = rule in RULES and after.startswith(":") and after[1:].strip() != ""
            pragmas.append([number, rule, None, ok])
        has_code = code_lines[idx].strip() != ""
        if has_code:
            for p in pending:
                pragmas[p][2] = number
            pending = []
            for p in range(before, len(pragmas)):
                pragmas[p][2] = number
        else:
            pending.extend(range(before, len(pragmas)))
    return pragmas


def count_bounded(code, pat):
    n, i = 0, 0
    while True:
        j = code.find(pat, i)
        if j == -1:
            return n
        left_ok = j == 0 or not is_ident(ord(code[j - 1]))
        after = j + len(pat)
        right_ok = after >= len(code) or not is_ident(ord(code[after]))
        if left_ok and right_ok:
            n += 1
            i = j + len(pat)
        else:
            i = j + 1


def count_spawn_calls(code):
    n, i = 0, 0
    pat = "spawn"
    while True:
        j = code.find(pat, i)
        if j == -1:
            return n
        ok_l = j == 0 or not is_ident(ord(code[j - 1]))
        after = j + len(pat)
        ok_r = after >= len(code) or not is_ident(ord(code[after]))
        if ok_l and ok_r:
            l = j
            while l > 0 and code[l - 1] == " ":
                l -= 1
            called_on = l > 0 and (code[l - 1] == "." or code[max(0, l - 2) : l] == "::")
            r = after
            while r < len(code) and code[r] == " ":
                r += 1
            invoked = r < len(code) and code[r] == "("
            if called_on and invoked:
                n += 1
            i = j + len(pat)
        else:
            i = j + 1


def match_count(rule, code):
    if rule == "D01":
        return sum(count_bounded(code, p) for p in ["HashMap", "HashSet"])
    if rule == "D02":
        return sum(
            count_bounded(code, p)
            for p in ["Instant::now", "SystemTime::now", "UNIX_EPOCH"]
        )
    if rule == "D03":
        pats = [
            "thread_rng",
            "from_entropy",
            "OsRng",
            "StdRng",
            "SmallRng",
            "getrandom",
            "RandomState",
            "DefaultHasher",
        ]
        return sum(count_bounded(code, p) for p in pats)
    if rule == "D04":
        return count_spawn_calls(code)
    if rule == "D05":
        pats = [".sum::<f32>(", ".sum::<f64>(", ".product::<f32>(", ".product::<f64>("]
        return sum(code.count(p) for p in pats)
    if rule == "D06":
        return count_bounded(code, "unsafe")
    if rule == "D07":
        return code.count(".unwrap()") + code.count(".expect(")
    raise AssertionError(rule)


def lint_file(rel, src):
    code, comment = mask(src)
    in_test = mark_test_scopes(code)
    pragmas = parse_pragmas(comment, code)
    counts = {}
    for rule, meta in RULES.items():
        if rel in meta["exempt"]:
            continue
        if meta["src_only"] and not rel.startswith("rust/src/"):
            continue
        for idx, line in enumerate(code):
            if meta["skip_test"] and in_test[idx]:
                continue
            hits = match_count(rule, line)
            if hits == 0:
                continue
            if rule == "D06":
                if "SAFETY:" in comment[idx] or (idx > 0 and "SAFETY:" in comment[idx - 1]):
                    continue
            if any(p[3] and p[1] == rule and p[2] == idx + 1 for p in pragmas):
                continue
            counts.setdefault(rule, []).append((idx + 1, hits))
    return counts


def main():
    root = Path(sys.argv[1]) if len(sys.argv) > 1 else Path(__file__).resolve().parents[2]
    files = []
    for r in ROOTS:
        d = root / r
        if d.is_dir():
            files += [p.relative_to(root).as_posix() for p in d.rglob("*.rs")]
    files.sort()

    per_rule = {}
    hard = []
    for rel in files:
        counts = lint_file(rel, (root / rel).read_bytes())
        for rule, sites in counts.items():
            total = sum(h for _, h in sites)
            if rule == "D07":
                per_rule.setdefault(rule, {})[rel] = total
            else:
                for line, hits in sites:
                    hard.append(f"{rel}:{line}: {rule} x{hits}")

    if hard:
        print("D01-D06 must be zero before a baseline can be cut:", file=sys.stderr)
        for h in hard:
            print(f"  {h}", file=sys.stderr)
        sys.exit(1)

    out = ['{\n  "version": 1,\n  "rules": {']
    rules_sorted = sorted((r, f) for r, f in per_rule.items() if f)
    for ri, (rule, by_file) in enumerate(rules_sorted):
        out.append("\n" if ri == 0 else ",\n")
        out.append(f'    "{rule}": {{')
        for fi, (rel, count) in enumerate(sorted(by_file.items())):
            out.append("\n" if fi == 0 else ",\n")
            out.append(f'      "{rel}": {count}')
        out.append("\n    }")
    out.append("}\n}\n" if not rules_sorted else "\n  }\n}\n")
    text = "".join(out)
    target = root / "xtask" / "lint-baseline.json"
    target.write_text(text)
    n_files = len(per_rule.get("D07", {}))
    n_sites = sum(per_rule.get("D07", {}).values())
    print(f"wrote {target}: D07 over {n_files} files, {n_sites} sites; {len(files)} files scanned")


if __name__ == "__main__":
    main()
